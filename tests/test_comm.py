"""The unified repro.comm API: WireSpec grammar + canonical round-trips
(and their equality with the legacy rung_key domain), the make_wire /
make_compressor back-compat shims, Compose precedence (budget caps rate,
outage overrides both), PlanBank compile counts under policy switching,
and the TrainSession driver."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adapt import (BudgetController, BudgetPolicy, BudgetSchedule,
                         PlanBank, SNRFeedbackPolicy, WallClockBudgetSchedule,
                         ladder_from_specs, rung_key)
from repro.comm import (OUTAGE_PLAN, BudgetComm, Compose, OutageComm,
                        PerLeafPlan, RateComm, StaticComm, StepTelemetry,
                        TrainSession, WireSpec, canonical_key)
from repro.core.compressors import (BlockedHybrid, Sparsifier, WireCompressor,
                                    make_compressor)
from repro.core.wire import HybridWire, Int8Wire, TernaryWire, make_wire
from repro.runtime.fault import OUTAGE_SPEC

# every spec-string shape the repo ships (default trainer ladder, fig4/fig5
# ladders, wire adapters, the blackout pseudo-spec)
REPO_SPECS = [
    "dense", "dense_bf16", "int8:block=256", "ternary:block=512",
    "hybrid:block=256,top_j=16", "hybrid:block=512,top_j=4",
    "randk:block=512,k=128", "topk:block=512,k=128",
    "identity", "ternary", "sparsifier:p=0.8", "lowprec:bits=6",
    "hybrid:eta=3.3", "blocked_ternary:block=16",
    "blocked_hybrid:block=512,top_j=4",
    "wire:ternary:block=64", "wire:int8:block=64", "outage",
]

DEFAULT_LADDER = ("dense", "int8:block=256", "hybrid:block=256,top_j=16",
                  "hybrid:block=512,top_j=4", "ternary:block=512")


# ---------------------------------------------------------------------------
# WireSpec grammar
# ---------------------------------------------------------------------------
class TestWireSpec:
    @pytest.mark.parametrize("spec", REPO_SPECS)
    def test_parse_canonical_roundtrip_idempotent(self, spec):
        w = WireSpec.parse(spec)
        assert w.canonical() == spec                      # repo specs ARE canonical
        assert WireSpec.parse(w.canonical()) == w         # parse . canonical = id
        assert WireSpec.parse(w) is w                     # idempotent on WireSpec
        assert hash(WireSpec.parse(spec)) == hash(w)      # hashable key

    @pytest.mark.parametrize("spec", DEFAULT_LADDER)
    def test_canonical_matches_legacy_rung_key(self, spec):
        # the PlanBank key domain is unchanged by the migration
        assert WireSpec.parse(spec).canonical() == rung_key(spec)

    def test_canonical_sorts_and_normalizes(self):
        a = WireSpec.parse("hybrid:top_j=4,block=512")
        b = WireSpec.parse("hybrid:block=512,top_j=4")
        assert a == b and a.canonical() == "hybrid:block=512,top_j=4"

    @pytest.mark.parametrize("bad", [
        "ternaryy", "hybrid:block", "hybrid:block=512,block=256",
        "wire:sparsifier:p=0.5", "outage:block=2", "hybrid:=4"])
    def test_malformed_specs_rejected_at_parse(self, bad):
        with pytest.raises(ValueError):
            WireSpec.parse(bad)

    def test_outage_spec_names_agree(self):
        assert WireSpec.parse("outage").is_outage
        assert WireSpec.parse(OUTAGE_SPEC).canonical() == OUTAGE_SPEC

    def test_level_dispatch(self):
        s = WireSpec.parse("ternary:block=64")
        assert isinstance(s.wire(), TernaryWire) and s.wire().block == 64
        # "ternary" means something different per level — both reachable
        assert WireSpec.parse("ternary").compressor().name == "ternary"
        with pytest.raises(ValueError):
            WireSpec.parse("sparsifier:p=0.5").wire()
        with pytest.raises(ValueError):
            WireSpec.parse("int8:block=64").compressor()
        with pytest.raises(ValueError):
            WireSpec.parse("outage").wire()
        with pytest.raises(ValueError):
            WireSpec.parse("randk:k=2.5").wire()   # no silent truncation


class TestFactoryShims:
    def test_make_wire_delegates(self):
        assert make_wire("hybrid:block=512,top_j=4") == HybridWire(
            block=512, top_j=4)
        assert make_wire("int8:block=256") == Int8Wire(block=256)
        assert make_wire(WireSpec.parse("ternary:block=64")) == TernaryWire(
            block=64)
        with pytest.raises(ValueError):
            make_wire("nope")

    def test_make_compressor_delegates(self):
        assert make_compressor("sparsifier:p=0.8") == Sparsifier(p=0.8)
        assert make_compressor("blocked_hybrid:block=512,top_j=4") == \
            BlockedHybrid(block=512, top_j=4)
        wc = make_compressor("wire:ternary:block=64")
        assert isinstance(wc, WireCompressor) and wc.fmt == TernaryWire(
            block=64)
        with pytest.raises(ValueError):
            make_compressor("nope:p=1")

    def test_ladder_from_specs_through_wirespec(self):
        # both registries, same strings — level picks the codec
        rungs = ladder_from_specs(("ternary:block=64",), level="wire")
        assert isinstance(rungs[0].codec, TernaryWire)


# ---------------------------------------------------------------------------
# PerLeafPlan keys
# ---------------------------------------------------------------------------
class TestPerLeafPlan:
    def test_uniform_collapses_like_rung_key(self):
        v = ("ternary:block=64",) * 5
        assert PerLeafPlan.vector(v).key() == rung_key(v) == "ternary:block=64"
        mixed = ("ternary:block=64", "dense", "ternary:block=64")
        assert PerLeafPlan.vector(mixed).key() == rung_key(mixed)

    def test_outage_and_from_key(self):
        assert OUTAGE_PLAN.key() == OUTAGE_SPEC
        assert PerLeafPlan.from_key(OUTAGE_SPEC) is OUTAGE_PLAN
        assert PerLeafPlan.from_key(None) is None
        assert PerLeafPlan.from_key("dense").key() == "dense"
        assert canonical_key(("dense", "int8:block=64")) == (
            "dense", "int8:block=64")
        # the typed OUTAGE WireSpec lifts to the real blackout plan (not a
        # bogus outage=False plan whose cost model would try .wire())
        from repro.comm import OUTAGE
        assert PerLeafPlan.from_key(OUTAGE) is OUTAGE_PLAN
        assert PerLeafPlan.uniform(OUTAGE).outage
        assert PerLeafPlan.vector([OUTAGE, OUTAGE]) is OUTAGE_PLAN
        with pytest.raises(ValueError):
            PerLeafPlan.vector(["dense", OUTAGE])


# ---------------------------------------------------------------------------
# Compose precedence (satellite: budget caps rate, outage overrides both)
# ---------------------------------------------------------------------------
LADDER = ("dense", "int8:block=64", "ternary:block=64")
SHAPES = ((4, 64), (130,))


def _budget_comm(bits, cadence=1, **kw):
    ctl = BudgetController(ladder=ladder_from_specs(LADDER, level="wire"),
                           shapes=SHAPES, neighbors=1, eta_min=1.0, **kw)
    pol = BudgetPolicy(controller=ctl, schedule=BudgetSchedule(bits=bits),
                       cadence=cadence)
    return BudgetComm(policy=pol)


def _telemetry(step, n=len(SHAPES), snr=10.0):
    d = np.full((n,), 100.0)
    return StepTelemetry(step=step, diff_power=d, noise_power=d / snr)


class TestCompose:
    def test_budget_caps_rates_choice(self):
        # rate proposes dense; the budget only affords ternary
        bc = _budget_comm(bits=0.0)
        dense_cost = bc.plan_cost(PerLeafPlan.uniform("dense"))
        tern_cost = bc.plan_cost(PerLeafPlan.uniform("ternary:block=64"))
        budget = (dense_cost + tern_cost) / 2
        bc.policy.schedule = BudgetSchedule(bits=budget)
        rate = StaticComm("dense")
        comp = Compose(rate, bc)
        plan = comp.decide(0)
        assert plan.key() != "dense"                  # capped: downgraded
        # ledger: the capped solve's bits were accounted and fit the budget
        step, bgt, _, bits, reason = bc.spend_log[-1]
        assert bits <= bgt * (1 + 1e-9) and reason != "proposal"
        assert bc.plan_cost(plan) == pytest.approx(bits)

    def test_budget_adopts_fitting_proposal_exactly(self):
        bc = _budget_comm(bits=0.0)
        dense_cost = bc.plan_cost(PerLeafPlan.uniform("dense"))
        bc.policy.schedule = BudgetSchedule(bits=dense_cost * 1.01)
        comp = Compose(StaticComm("dense"), bc)
        plan = comp.decide(0)
        assert plan.key() == "dense"                  # proposal fits: adopted
        assert bc.spend_log[-1][3] == pytest.approx(dense_cost)
        assert bc.spend_log[-1][4] == "proposal"

    def test_outage_overrides_rate_and_budget(self):
        bc = _budget_comm(bits=1e12)                  # budget affords dense
        comp = Compose(StaticComm("dense"), bc,
                       OutageComm(windows=((2, 4),)))
        keys = [comp.decide(s).key() for s in range(6)]
        assert keys == ["dense", "dense", OUTAGE_SPEC, OUTAGE_SPEC,
                        "dense", "dense"]
        # blackout steps cost zero in the budget ledger
        for step, _, _, bits, reason in bc.spend_log:
            assert (bits == 0.0) == (2 <= step < 4)

    def test_compose_observe_fans_out(self):
        rate = RateComm(policy=SNRFeedbackPolicy(
            ladder=LADDER, eta_min=1.0, cadence=1), n_leaves=2, cadence=1)
        bc = _budget_comm(bits=1e12)
        comp = Compose(rate, bc, OutageComm())
        comp.decide(0)
        comp.observe(_telemetry(0))
        assert int(rate.telemetry.count) == 1         # rate saw the sample
        assert bc._snap is not None and bc._snap.n_layers == 2

    def test_blackout_telemetry_skips_rate_members(self):
        # a W_t=I step's noise power is 0 -> fake-infinite SNR; the rate
        # member must not fold it into its EMA (spurious post-outage
        # downgrade), while the budget member still sees the sample
        rate = RateComm(policy=SNRFeedbackPolicy(
            ladder=LADDER, eta_min=1.0, cadence=1), n_leaves=2, cadence=1)
        bc = _budget_comm(bits=1e12)
        comp = Compose(rate, bc, OutageComm(windows=((0, 1),)))
        assert comp.decide(0).outage
        comp.observe(StepTelemetry(step=0, diff_power=np.ones(2),
                                   noise_power=np.zeros(2)))
        assert int(rate.telemetry.count) == 0      # skipped
        assert bc._snap is not None                # budget still fed
        assert not comp.decide(1).outage
        comp.observe(_telemetry(1))
        assert int(rate.telemetry.count) == 1      # transmitting steps count

    def test_telemetry_gating_attribute(self):
        assert StaticComm("dense").consumes_telemetry is False
        assert OutageComm().consumes_telemetry is False
        assert Compose(StaticComm("dense"), OutageComm()) \
            .consumes_telemetry is False
        assert Compose(StaticComm("dense"),
                       _budget_comm(bits=1.0)).consumes_telemetry is True

    def test_rate_walks_ladder_under_compose(self):
        # huge measured SNR -> the feedback policy steps down the ladder,
        # and a generous budget adopts each proposal verbatim
        rate = RateComm(policy=SNRFeedbackPolicy(
            ladder=LADDER, eta_min=1.0, margin=1.0, upgrade=1.5, cadence=1),
            n_leaves=2, cadence=1)
        comp = Compose(rate, _budget_comm(bits=1e12))
        plan = comp.decide(0)
        assert plan.key() == "dense"
        seen = [plan.key()]
        for s in range(1, 4):
            comp.observe(_telemetry(s - 1, snr=1e6))
            seen.append(comp.decide(s).key())
        assert seen[-1] != "dense"                    # moved down-ladder


# ---------------------------------------------------------------------------
# PlanBank compile counts across policy switches (satellite)
# ---------------------------------------------------------------------------
class TestNoRecompileOnPolicySwitch:
    def test_composed_session_compiles_at_most_ladder_size(self):
        """A full composed session (rate + budget + outage) cycling plans
        never compiles more than |ladder| + 1 (outage) distinct steps —
        policy switching is a dict lookup."""
        traces = []

        def build(key):
            @jax.jit
            def f(state):
                traces.append(key)
                return state + 1.0, {
                    "diff_power_leaves": jnp.full((len(SHAPES),), 100.0),
                    "noise_power_leaves": jnp.full((len(SHAPES),), 10.0)}
            f(jnp.zeros(()))          # compile eagerly: traces == builds
            return f

        bank = PlanBank(build, max_size=len(LADDER) + 1)
        rate = RateComm(policy=SNRFeedbackPolicy(
            ladder=LADDER, eta_min=1.0, margin=1.0, upgrade=1.2, cadence=2),
            n_leaves=len(SHAPES), cadence=2)
        comp = Compose(rate, _budget_comm(bits=1e12),
                       OutageComm(windows=((5, 8), (12, 15))))
        session = TrainSession(bank=bank, policy=comp,
                               state=jnp.zeros(()))
        res = session.run(30)
        distinct = set(res.plan_per_step)
        assert OUTAGE_SPEC in distinct and len(distinct) >= 3
        assert bank.builds == len(set(traces)) == len(distinct)
        assert bank.builds <= len(LADDER) + 1
        assert bank.hits == 30 - bank.builds
        assert bank.evictions == 0


# ---------------------------------------------------------------------------
# TrainSession driver contract
# ---------------------------------------------------------------------------
class TestTrainSession:
    @staticmethod
    def _counting_bank():
        def build(key):
            def f(state, batch):
                return state + batch, {"loss": jnp.asarray(float(len(key)))}
            return f
        return PlanBank(build, max_size=4)

    def test_batch_fn_hooks_and_history(self):
        logged, switches = [], []
        session = TrainSession(
            bank=self._counting_bank(), policy=StaticComm("dense"),
            state=jnp.zeros(()), batch_fn=lambda i: jnp.asarray(1.0),
            log_every=2, on_log=lambda i, m, ran: logged.append((i, ran)),
            on_switch=lambda s, a, b: switches.append((s, a, b)))
        res = session.run(5)
        assert float(res.state) == 5.0
        assert [i for i, _ in logged] == [1, 3, 4]    # every 2 + final
        assert switches == [] and res.wire_log == [(0, "dense")]
        assert len(res.history) == 5 and res.n_steps == 5
        assert res.metrics_arrays()["loss"].shape == (5,)

    def test_no_phantom_decision_for_unrun_step(self):
        # the budget ledger gets exactly one entry per EXECUTED step
        bc = _budget_comm(bits=1e12)
        session = TrainSession(
            bank=self._counting_bank(), policy=bc, state=jnp.zeros(()),
            batch_fn=lambda i: jnp.asarray(1.0))
        session.run(4)
        assert [s for s, *_ in bc.spend_log] == [0, 1, 2, 3]
        # an empty run (resume at/after the end) charges NOTHING
        res = session.run(4, start_step=4)
        assert res.n_steps == 0 and res.plan_per_step == []
        assert [s for s, *_ in bc.spend_log] == [0, 1, 2, 3]

    def test_wall_clock_budget_coupling(self):
        """Deadline-aware budgets: the session's measured wall times reach
        the schedule, and a slow step shrinks the live budget."""
        # budget generous enough that the plan never switches: only the
        # (compiled) first step's wall time is excluded
        sched = BudgetSchedule.from_wall_clock(slo_ms=1e9, bits=1e12,
                                               decay=0.0)
        ctl = BudgetController(
            ladder=ladder_from_specs(LADDER, level="wire"),
            shapes=SHAPES, neighbors=1, eta_min=1.0)
        bc = BudgetComm(policy=BudgetPolicy(controller=ctl, schedule=sched,
                                            cadence=1))

        def build(key):
            def f(state):
                return state, {"diff_power_leaves": np.ones(len(SHAPES)),
                               "noise_power_leaves": np.ones(len(SHAPES))}
            return f

        session = TrainSession(bank=PlanBank(build), policy=bc,
                               state=jnp.zeros(()))
        session.run(3)
        # step 0 built (compiled) its plan: its wall time is the compiler's,
        # not the link's, and must NOT reach the schedule
        assert sched.samples == 2 and sched.ema_ms is not None
        # an SLO far above any measured step time maxes the scale
        assert sched.scale() == sched.max_scale


# ---------------------------------------------------------------------------
# deadline-aware schedule unit behavior (satellite)
# ---------------------------------------------------------------------------
class TestWallClockSchedule:
    def test_scaling_and_clamps(self):
        s = BudgetSchedule.from_wall_clock(slo_ms=100.0, bits=1000.0,
                                           decay=0.0, min_scale=0.1,
                                           max_scale=2.0)
        assert isinstance(s, WallClockBudgetSchedule)
        assert s.budget_at(0) == 1000.0               # no measurement yet
        s.record_wall_time(100.0)
        assert s.budget_at(1) == pytest.approx(1000.0)   # on-SLO: unscaled
        s.record_wall_time(200.0)                     # 2x slow -> half budget
        assert s.budget_at(2) == pytest.approx(500.0)
        s.record_wall_time(1e9)                       # clamped at min_scale
        assert s.budget_at(3) == pytest.approx(100.0)
        s.record_wall_time(1.0)                       # clamped at max_scale
        assert s.budget_at(4) == pytest.approx(2000.0)
        s.record_wall_time(-5.0)                      # garbage ignored
        assert s.samples == 4

    def test_wraps_any_base_schedule(self):
        base = BudgetSchedule(bits=80.0, kind="duty", period=4, duty=0.5,
                              off_bits=0.0)
        s = BudgetSchedule.from_wall_clock(slo_ms=100.0, bits=80.0,
                                           base=base, decay=0.0)
        s.record_wall_time(200.0)
        assert s.budget_at(0) == pytest.approx(40.0)  # scaled on-phase
        assert s.budget_at(2) == 0.0                  # off-phase stays 0
