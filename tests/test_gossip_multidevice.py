"""Multi-device gossip semantics (subprocess with 8 virtual devices):
  * shard_map ppermute gossip == dense W @ C(d) mixing,
  * wire bytes on the links (collective-permute operands are packed arrays),
  * straggler drop-renormalize keeps W_t doubly stochastic,
  * node-stacked trainer step == reference stacked math.
"""
import pytest

from conftest import run_in_devices

pytestmark = pytest.mark.multidevice


def test_gossip_equals_dense_mixing():
    out = run_in_devices(8, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, set_mesh
        from jax.sharding import PartitionSpec as P
        from repro.core.wire import make_wire
        from repro.core.gossip import make_plan, build_gossip_fn
        mesh = make_mesh((2, 4), ("pod", "data"))
        key = jax.random.PRNGKey(0)
        fmt = make_wire("hybrid:block=64,top_j=2")
        plan = make_plan(mesh, ("pod", "data"), fmt)
        assert plan.mode == "circulant", plan.mode
        d = {"a": jax.random.normal(key, (8, 5, 128)),
             "b": jax.random.normal(key, (8, 64))}
        specs = {"a": P(("pod","data"), None, None), "b": P(("pod","data"), None)}
        fn = build_gossip_fn(plan, mesh, specs)
        c_own, agg = jax.jit(fn)(key, d)
        W = jnp.asarray(plan.W, jnp.float32)
        for k in d:
            ref = jnp.einsum("mn,n...->m...", W, np.asarray(c_own[k]))
            err = float(jnp.abs(ref - agg[k]).max())
            assert err < 1e-5, (k, err)
        print("OK")
    """)
    assert "OK" in out


def test_collective_permute_carries_packed_bytes():
    out = run_in_devices(8, """
        import jax, jax.numpy as jnp, re
        from repro.compat import make_mesh, set_mesh
        from jax.sharding import PartitionSpec as P
        from repro.core.wire import make_wire
        from repro.core.gossip import make_plan, build_gossip_fn
        mesh = make_mesh((8,), ("data",))
        fmt = make_wire("ternary:block=512")
        plan = make_plan(mesh, ("data",), fmt)
        d = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4, 2048))}
        fn = build_gossip_fn(plan, mesh, {"w": P("data", None, None)})
        txt = jax.jit(fn).lower(jax.random.PRNGKey(0), d).compile().as_text()
        # the permuted operands must include u8 packed codes, NOT f32 full
        cp_lines = [l for l in txt.splitlines() if "collective-permute(" in l]
        assert any("u8[" in l for l in cp_lines), cp_lines
        # f32 permutes only for the tiny per-tile scales (4 tiles/row)
        f32 = [l for l in cp_lines if "f32[" in l]
        for l in f32:
            m = re.search(r"f32\\[([\\d,]+)\\]", l)
            n = 1
            for x in m.group(1).split(","):
                n *= int(x)
            assert n <= 4 * 4 * 2048 // 512, l   # scales only
        print("OK", len(cp_lines))
    """)
    assert "OK" in out


def test_straggler_drop_renormalize():
    out = run_in_devices(8, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, set_mesh
        from repro.core.wire import DenseWire
        from repro.core.gossip import make_plan, mesh_consensus_matrix
        from repro.runtime.fault import drop_renormalize_plan, StragglerSim
        mesh = make_mesh((8,), ("data",))
        plan = make_plan(mesh, ("data",), DenseWire())
        nz = [i for i, (o, w) in enumerate(plan.offsets) if any(o)]
        eff = drop_renormalize_plan(plan, [nz[0]])
        # effective W from offsets must be doubly stochastic
        n = plan.n_nodes
        W = np.zeros((n, n))
        for off, w in eff:
            for i in range(n):
                W[(i + off[0]) % n, i] += w
        assert np.allclose(W.sum(0), 1) and np.allclose(W.sum(1), 1)
        assert np.allclose(W, W.T)
        sim = StragglerSim(prob=0.5, seed=1)
        ds = [sim.dropped(t, 2) for t in range(20)]
        assert any(ds) and not all(len(d) == 2 for d in ds)
        print("OK")
    """)
    assert "OK" in out


def test_trainer_node_mode_loss_decreases():
    out = run_in_devices(8, """
        import jax
        from repro.compat import make_mesh, set_mesh
        from repro.configs import get_smoke
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.train import make_trainer
        from repro.data import SyntheticLMData
        mesh = make_mesh((4, 2), ("data", "model"))
        arch = get_smoke("qwen3-8b")
        shape = ShapeConfig("t", 64, 8, "train")
        run = RunConfig(consensus_axis="data", wire="hybrid:block=64,top_j=4",
                        alpha=0.05, optimizer="adam", grad_accum=2)
        tr = make_trainer(mesh, arch, run, shape)
        assert tr.n_nodes == 4
        state = tr.init_state(0)
        step = tr.jit_train_step()
        data = SyntheticLMData(vocab_size=arch.vocab_size, seq_len=64,
                               global_batch=8, n_nodes=4, iid=False)
        with set_mesh(mesh):
            losses = []
            for i in range(15):
                state, m = step(state, data.batch(i))
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        # consensus states stay finite; noise self-reduces vs early steps
        assert all(l == l for l in losses)
        print("OK", round(losses[0], 3), "->", round(losses[-1], 3))
    """, timeout=560)
    assert "OK" in out


def test_fsdp_pod_consensus_mode():
    out = run_in_devices(8, """
        import jax
        from repro.compat import make_mesh, set_mesh
        from repro.configs import get_smoke
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.train import make_trainer
        from repro.data import SyntheticLMData
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        arch = get_smoke("qwen1.5-32b")
        shape = ShapeConfig("t", 64, 8, "train")
        run = RunConfig(consensus_axis="pod", param_mode="fsdp_tp",
                        wire="int8:block=64", alpha=0.02, optimizer="adam")
        tr = make_trainer(mesh, arch, run, shape)
        assert tr.n_nodes == 2 and tr.consensus_axes == ("pod",)
        state = tr.init_state(0)
        step = tr.jit_train_step()
        data = SyntheticLMData(vocab_size=arch.vocab_size, seq_len=64,
                               global_batch=8, n_nodes=2)
        losses = []
        with set_mesh(mesh):
            for i in range(16):
                state, m = step(state, data.batch(i))
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print("OK", round(losses[0], 3), "->", round(losses[-1], 3))
    """, timeout=560)
    assert "OK" in out
