"""Property tests locking down the flat-wire contract the adapt and budget
controllers build on (ISSUE 3):

  * every explicit-RNG row codec in core.wire (int8 / ternary / hybrid /
    randk) encodes+decodes on the flat row buffer BIT-EXACTLY like the
    per-leaf reference WireFormat under the same PRNG key, for random
    shapes and random per-leaf rung mixes (the flat_gossip_exchange
    parity invariant);
  * the measured noise power ||C(z) - z||^2 of every explicit-RNG format
    is statistically consistent with its closed-form
    ``expected_noise_power`` oracle (the candidate-SNR model BOTH the
    RateController and the BudgetController trust).

Hypothesis drives the randomization when installed (deterministically:
conftest registers a derandomized bounded profile, and
``scripts/run_tests.sh --hypothesis`` pins ``--hypothesis-seed=0``);
the seeded parametrized tests below exercise the same check functions
either way, so the invariants stay covered when hypothesis is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gossip as G
from repro.core import wire as W
from repro.core.wire import make_wire

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # degrade to the seeded fallback tests only
    HAVE_HYPOTHESIS = False

# the explicit-RNG row codecs (wire.needs_rng); dense/topk/lowrank are
# RNG-free (lowrank is DETERMINISTIC: its stateless encode cold-starts
# from a fixed orthonormal seed, so the oracle check is an identity and
# the flat-vs-leaf parity must be bitwise even without a shared key)
RNG_SPECS = ("int8:block=64", "ternary:block=128",
             "hybrid:block=128,top_j=4", "randk:block=128,k=32")
ALL_SPECS = RNG_SPECS + ("dense", "topk:block=128,k=32",
                         "lowrank:block=64,r=2")

N_MC = 96   # Monte-Carlo draws for the oracle consistency check


# ---------------------------------------------------------------------------
# check functions (shared by the hypothesis and the seeded tests)
# ---------------------------------------------------------------------------
def _single_node_plan(fmts):
    return G.GossipPlan(consensus_axes=(), dims=(), n_nodes=1,
                        mode="circulant", offsets=(), W=np.ones((1, 1)),
                        fmt=fmts[0], leaf_fmts=tuple(fmts))


def check_flat_matches_leaf(shapes, specs, seed):
    """flat_gossip_exchange decode == per-leaf gossip_exchange decode,
    bit for bit, same PRNG key (single-node plan: pure codec parity)."""
    key = jax.random.PRNGKey(seed)
    leaves = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), s)
              * (1.0 + 3.0 * i)
              for i, s in enumerate(shapes)}
    fmts = [make_wire(s) for s in specs]
    plan = _single_node_plan(fmts)
    c_leaf, _ = G.gossip_exchange(plan, key, leaves)
    c_flat, _ = G.flat_gossip_exchange(plan, key, leaves)
    for k in leaves:
        np.testing.assert_array_equal(
            np.asarray(c_leaf[k]), np.asarray(c_flat[k]),
            err_msg=f"leaf {k} specs {specs} shapes {shapes} seed {seed}")


def check_noise_oracle(spec, shape, seed, scale=1.0, n=N_MC):
    """Monte-Carlo mean of ||decode(encode(z)) - z||^2 must sit within the
    sampling tolerance of the closed-form expected_noise_power oracle."""
    fmt = make_wire(spec)
    z = jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
    pred = float(fmt.expected_noise_power(z))
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), n)

    def one(k):
        dec = fmt.decode(fmt.encode(k, z), z.shape, jnp.float32)
        return jnp.sum((dec - z.astype(jnp.float32)) ** 2)

    draws = np.asarray(jax.vmap(one)(keys), np.float64)
    mc, se = float(draws.mean()), float(draws.std() / np.sqrt(n))
    power = float(jnp.sum(z.astype(jnp.float32) ** 2))
    tol = 6.0 * se + 1e-6 * (power + 1.0)
    assert abs(mc - pred) <= tol, \
        (f"{spec} shape {shape} seed {seed} scale {scale}: "
         f"MC {mc:.6g} vs oracle {pred:.6g} (tol {tol:.3g})")


# ---------------------------------------------------------------------------
# hypothesis-driven randomization
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    _last = st.integers(1, 300)
    _lead = st.integers(1, 4)
    _shape = st.one_of(
        st.tuples(_last),
        st.tuples(_lead, _last),
        st.tuples(_lead, st.integers(1, 3), _last),
    )
    _tree = st.lists(st.tuples(_shape, st.sampled_from(ALL_SPECS)),
                     min_size=1, max_size=4)

    @settings(deadline=None)
    @given(tree=_tree, seed=st.integers(0, 2 ** 16 - 1))
    def test_row_codec_roundtrip_property(tree, seed):
        shapes = [t[0] for t in tree]
        specs = [t[1] for t in tree]
        check_flat_matches_leaf(shapes, specs, seed)

    @settings(deadline=None)
    @given(spec=st.sampled_from(RNG_SPECS),
           shape=_shape,
           seed=st.integers(0, 2 ** 16 - 1),
           scale=st.sampled_from([0.02, 1.0, 40.0]))
    def test_noise_oracle_property(spec, shape, seed, scale):
        check_noise_oracle(spec, shape, seed, scale=scale)

    # lowrank over random ranks / tile geometries / iteration counts: the
    # flat-vs-leaf parity must be BITWISE (deterministic codec) and the
    # exact residual oracle must match the measured residual identically
    @settings(deadline=None)
    @given(block=st.sampled_from([16, 64]),
           r=st.integers(1, 4),
           iters=st.integers(1, 2),
           shape=_shape,
           seed=st.integers(0, 2 ** 16 - 1),
           scale=st.sampled_from([0.02, 1.0, 40.0]))
    def test_lowrank_roundtrip_and_oracle_property(block, r, iters, shape,
                                                   seed, scale):
        spec = f"lowrank:block={block},iters={iters},r={r}"
        check_flat_matches_leaf([shape], [spec], seed)
        check_noise_oracle(spec, shape, seed, scale=scale, n=4)


# ---------------------------------------------------------------------------
# seeded coverage (runs with or without hypothesis)
# ---------------------------------------------------------------------------
_SEEDED_TREES = [
    # every RNG codec alone, awkward shapes (padding on both axes)
    ([(257,)], ["int8:block=64"]),
    ([(3, 130)], ["ternary:block=128"]),
    ([(2, 2, 200)], ["hybrid:block=128,top_j=4"]),
    ([(150,)], ["randk:block=128,k=32"]),
    # lowrank alone: padded tail, multi-tile rows, rank at the tile cap
    ([(257,)], ["lowrank:block=64,r=2"]),
    ([(3, 130)], ["lowrank:block=16,iters=2,r=4"]),
    # mixed rung vector incl. the RNG-free codecs, ragged shapes
    ([(3, 70), (130,), (2, 2, 128), (1,), (260,), (5, 40)],
     ["ternary:block=128", "dense", "hybrid:block=128,top_j=4",
      "int8:block=64", "randk:block=128,k=32", "topk:block=128,k=32"]),
    # ... and with a lowrank rung composed into the same flat row buffer
    ([(3, 70), (200,), (2, 128)],
     ["int8:block=64", "lowrank:block=64,r=3", "ternary:block=128"]),
]


@pytest.mark.parametrize("shapes,specs", _SEEDED_TREES)
@pytest.mark.parametrize("seed", [0, 12345])
def test_row_codec_roundtrip_seeded(shapes, specs, seed):
    check_flat_matches_leaf(shapes, specs, seed)


@pytest.mark.parametrize("spec", RNG_SPECS)
@pytest.mark.parametrize("shape,scale", [((3, 130), 1.0), ((257,), 40.0)])
def test_noise_oracle_seeded(spec, shape, scale):
    check_noise_oracle(spec, shape, seed=7, scale=scale)


@pytest.mark.parametrize("spec", ["lowrank:block=64,r=1",
                                  "lowrank:block=64,r=2",
                                  "lowrank:block=16,iters=2,r=4"])
@pytest.mark.parametrize("shape,scale", [((3, 130), 1.0), ((257,), 40.0),
                                         ((2, 128), 0.02)])
def test_lowrank_noise_oracle_seeded(spec, shape, scale):
    # deterministic codec: the MC "mean" is the exact residual, so the
    # oracle must match to float tolerance (n=4 just proves invariance)
    check_noise_oracle(spec, shape, seed=7, scale=scale, n=4)
