"""Flat-wire gossip path: FlatWirePlan metadata, row-codec parity with the
per-leaf formats, bit-exactness of flat_gossip_exchange vs gossip_exchange
(circulant AND dense modes, mixed per-leaf rungs, Pallas backend), and the
rung-vector plumbing (PlanBank keys, PerLeafSNRPolicy, trainer plans)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_devices

from repro.core import wire as W
from repro.core.wire import make_wire


# ---------------------------------------------------------------------------
# plan metadata
# ---------------------------------------------------------------------------
def test_flat_plan_layout_and_grouping():
    shapes = [(3, 700), (130,), (2, 5, 512), (260,)]
    dtypes = ["float32"] * 4
    fmts = [make_wire(s) for s in ("ternary:block=512", "dense",
                                   "ternary:block=512", "int8:block=256")]
    plan = W.make_flat_plan(shapes, dtypes, fmts)
    assert plan.block == 512           # lcm(512, 256) with dense blockless
    # groups in first-appearance order: ternary {0,2}, dense {1}, int8 {3}
    assert len(plan.groups) == 3
    assert [s.index for s in plan.segments] == [0, 2, 1, 3]
    # rows: leaf0 3*ceil(700/512)=3*2=6; leaf2 10*1=10; leaf1 1; leaf3 1
    assert [s.rows for s in plan.segments] == [6, 10, 1, 1]
    assert plan.total_rows == 18
    g0 = plan.groups[0]
    assert g0.rows == 16 and g0.row_start == 0
    # segments tile contiguously inside their group
    for g in plan.groups:
        segs = plan.group_segments(plan.groups.index(g))
        rows = sorted((s.row_start, s.rows) for s in segs)
        cur = g.row_start
        for start, n in rows:
            assert start == cur
            cur += n
        assert cur == g.row_start + g.rows


def test_flat_plan_rejects_misaligned_blocks():
    with pytest.raises(ValueError):
        W.make_flat_plan([(512,)], ["float32"],
                         [make_wire("ternary:block=384")], block=512)


def test_flatten_unflatten_roundtrip():
    key = jax.random.PRNGKey(0)
    leaves = [jax.random.normal(jax.random.fold_in(key, i), s)
              for i, s in enumerate([(3, 700), (130,), (2, 5, 512)])]
    fmts = [make_wire("ternary:block=512")] * 3
    plan = W.make_flat_plan([l.shape for l in leaves],
                            [l.dtype for l in leaves], fmts)
    buf = W.flatten_rows(plan, leaves)
    assert buf.shape == (plan.total_rows, plan.block)
    group_rows = [buf[g.row_start:g.row_start + g.rows] for g in plan.groups]
    back = W.unflatten_rows(plan, group_rows)
    for a, b in zip(leaves, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# row codecs: single-node (n=1) exchange parity exercises encode+decode of
# every format against the per-leaf WireFormat path, same PRNG key
# ---------------------------------------------------------------------------
SPECS = ["dense", "dense_bf16", "int8:block=256", "ternary:block=512",
         "hybrid:block=512,top_j=4", "randk:block=512,k=64",
         "topk:block=512,k=64"]


@pytest.mark.parametrize("spec", SPECS)
def test_row_codec_matches_leaf_codec(spec):
    """row_encode/row_decode on the flat buffer reproduce WireFormat
    encode/decode bit-for-bit under the same per-leaf key streams."""
    from repro.core import gossip as G
    key = jax.random.PRNGKey(7)
    leaves = {"a": jax.random.normal(key, (3, 700)) * 2,
              "b": jax.random.normal(jax.random.fold_in(key, 1), (130,)),
              "c": jax.random.normal(jax.random.fold_in(key, 2), (2, 5, 512))}
    fmt = make_wire(spec)
    plan = G.GossipPlan(consensus_axes=(), dims=(), n_nodes=1,
                        mode="circulant", offsets=(), W=np.ones((1, 1)),
                        fmt=fmt)
    c_leaf, _ = G.gossip_exchange(plan, key, leaves)
    c_flat, _ = G.flat_gossip_exchange(plan, key, leaves)
    for k in leaves:
        np.testing.assert_array_equal(np.asarray(c_leaf[k]),
                                      np.asarray(c_flat[k]), err_msg=k)


def test_row_codec_mixed_rungs_single_node():
    from repro.core import gossip as G
    key = jax.random.PRNGKey(3)
    leaves = {"a": jax.random.normal(key, (3, 700)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (130,)),
              "c": jax.random.normal(jax.random.fold_in(key, 2), (2, 5, 512)),
              "d": jax.random.normal(jax.random.fold_in(key, 3), (260,))}
    fmts = tuple(make_wire(s) for s in
                 ("ternary:block=512", "dense", "hybrid:block=512,top_j=4",
                  "int8:block=256"))
    plan = G.GossipPlan(consensus_axes=(), dims=(), n_nodes=1,
                        mode="circulant", offsets=(), W=np.ones((1, 1)),
                        fmt=fmts[0], leaf_fmts=fmts)
    c_leaf, _ = G.gossip_exchange(plan, key, leaves)
    c_flat, _ = G.flat_gossip_exchange(plan, key, leaves)
    for k in leaves:
        np.testing.assert_array_equal(np.asarray(c_leaf[k]),
                                      np.asarray(c_flat[k]), err_msg=k)


# ---------------------------------------------------------------------------
# multi-device bit-exactness (the acceptance gate)
# ---------------------------------------------------------------------------
_PARITY_PRELUDE = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh
    from jax.sharding import PartitionSpec as P
    from repro.core.wire import make_wire
    from repro.core.gossip import make_plan, build_gossip_fn

    key = jax.random.PRNGKey(0)
    d = {'a': jax.random.normal(key, (8, 3, 700)),
         'b': jax.random.normal(jax.random.PRNGKey(5), (8, 130)),
         'c': jax.random.normal(jax.random.PRNGKey(7), (8, 2, 5, 512)),
         'e': jax.random.normal(jax.random.PRNGKey(9), (8, 260))}

    def parity(mesh, axes, specs, **pkw):
        fmt = make_wire('ternary:block=512')
        pl_leaf = make_plan(mesh, axes, fmt, wire_path='leaf', **pkw)
        pl_flat = make_plan(mesh, axes, fmt, wire_path='flat', **pkw)
        cl, al = jax.jit(build_gossip_fn(pl_leaf, mesh, specs))(key, d)
        cf, af = jax.jit(build_gossip_fn(pl_flat, mesh, specs))(key, d)
        for k in d:
            assert np.array_equal(np.asarray(cl[k]), np.asarray(cf[k])), k
            assert np.array_equal(np.asarray(al[k]), np.asarray(af[k])), k
        return pl_flat.mode
"""

@pytest.mark.multidevice
def test_flat_bit_exact_ring_circulant():
    out = run_in_devices(8, _PARITY_PRELUDE + """
    mesh = make_mesh((8,), ('data',))
    specs = {'a': P('data', None, None), 'b': P('data', None),
             'c': P('data', None, None, None), 'e': P('data', None)}
    mode = parity(mesh, ('data',), specs)
    assert mode == 'circulant', mode
    print('OK', mode)
    """)
    assert "OK circulant" in out


@pytest.mark.multidevice
def test_flat_bit_exact_torus_2d():
    out = run_in_devices(8, _PARITY_PRELUDE + """
    mesh = make_mesh((2, 4), ('pod', 'data'))
    specs = {'a': P(('pod','data'), None, None), 'b': P(('pod','data'), None),
             'c': P(('pod','data'), None, None, None),
             'e': P(('pod','data'), None)}
    mode = parity(mesh, ('pod', 'data'), specs)
    assert mode == 'circulant', mode
    print('OK', mode)
    """)
    assert "OK circulant" in out


@pytest.mark.multidevice
def test_flat_bit_exact_dense_fallback():
    out = run_in_devices(8, _PARITY_PRELUDE + """
    from repro.core import consensus as cons
    mesh = make_mesh((8,), ('data',))
    specs = {'a': P('data', None, None), 'b': P('data', None),
             'c': P('data', None, None, None), 'e': P('data', None)}
    # irregular (non-circulant) graph -> dense all-gather fallback
    A = np.zeros((8, 8))
    for i, j in [(0,1),(0,3),(1,2),(2,5),(3,4),(4,5),(5,6),(6,7),(7,0),(2,7)]:
        A[i, j] = A[j, i] = 1
    Wd = cons.metropolis_weights(A, lazy=0.25)
    mode = parity(mesh, ('data',), specs, W=Wd)
    assert mode == 'dense', mode
    print('OK', mode)
    """)
    assert "OK dense" in out


@pytest.mark.multidevice
def test_flat_bit_exact_mixed_rungs_and_pallas():
    out = run_in_devices(8, _PARITY_PRELUDE + """
    mesh = make_mesh((8,), ('data',))
    specs = {'a': P('data', None, None), 'b': P('data', None),
             'c': P('data', None, None, None), 'e': P('data', None)}
    mixed = tuple(make_wire(s) for s in
                  ('ternary:block=512', 'dense', 'hybrid:block=512,top_j=4',
                   'int8:block=256'))
    pl_leaf = make_plan(mesh, ('data',), mixed[0], wire_path='leaf',
                        leaf_fmts=mixed)
    pl_pal = make_plan(mesh, ('data',), mixed[0], wire_path='flat',
                       use_pallas=True, leaf_fmts=mixed)
    cl, al = jax.jit(build_gossip_fn(pl_leaf, mesh, specs))(key, d)
    cf, af = jax.jit(build_gossip_fn(pl_pal, mesh, specs))(key, d)
    for k in d:
        assert np.array_equal(np.asarray(cl[k]), np.asarray(cf[k])), k
        assert np.array_equal(np.asarray(al[k]), np.asarray(af[k])), k
    print('OK')
    """)
    assert "OK" in out


@pytest.mark.multidevice
def test_flat_bit_exact_bf16_tree_with_pallas():
    """Non-f32 trees: the per-leaf path rounds every neighbor's decode
    through the leaf dtype; the flat path must replay that (cast_rows_like)
    — and the fused Pallas axpy, which can't, must fall back to the jnp
    rows codec for non-f32 groups rather than silently diverge."""
    out = run_in_devices(8, """
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh
    from jax.sharding import PartitionSpec as P
    from repro.core.wire import make_wire
    from repro.core.gossip import make_plan, build_gossip_fn

    mesh = make_mesh((8,), ('data',))
    key = jax.random.PRNGKey(0)
    d = {'a': jax.random.normal(key, (8, 3, 700)).astype(jnp.bfloat16),
         'b': jax.random.normal(jax.random.PRNGKey(5), (8, 520)
                                ).astype(jnp.bfloat16)}
    specs = {'a': P('data', None, None), 'b': P('data', None)}
    fmt = make_wire('ternary:block=512')
    pl_leaf = make_plan(mesh, ('data',), fmt, wire_path='leaf')
    for use_pallas in (False, True):
        pl_flat = make_plan(mesh, ('data',), fmt, wire_path='flat',
                            use_pallas=use_pallas)
        cl, al = jax.jit(build_gossip_fn(pl_leaf, mesh, specs))(key, d)
        cf, af = jax.jit(build_gossip_fn(pl_flat, mesh, specs))(key, d)
        for k in d:
            assert np.array_equal(np.asarray(cl[k], np.float32),
                                  np.asarray(cf[k], np.float32)), (use_pallas, k)
            assert np.array_equal(np.asarray(al[k], np.float32),
                                  np.asarray(af[k], np.float32)), (use_pallas, k)
    print('OK')
    """)
    assert "OK" in out


@pytest.mark.multidevice
def test_flat_moves_fewer_collectives():
    """The fused path must move ONE buffer per wire part per offset —
    collective-permute count independent of leaf count — and keep packed
    u8 codes (not decoded f32) on the links."""
    out = run_in_devices(8, """
    import jax, numpy as np
    from repro.compat import make_mesh
    from jax.sharding import PartitionSpec as P
    from repro.core.wire import make_wire
    from repro.core.gossip import make_plan, build_gossip_fn
    from repro.launch.hlo_stats import analyze

    mesh = make_mesh((8,), ('data',))
    key = jax.random.PRNGKey(0)
    d = {f'l{i}': jax.random.normal(jax.random.PRNGKey(i), (8, 4, 700))
         for i in range(6)}
    specs = {k: P('data', None, None) for k in d}
    fmt = make_wire('ternary:block=512')
    counts = {}
    for path in ('leaf', 'flat'):
        plan = make_plan(mesh, ('data',), fmt, wire_path=path)
        fn = jax.jit(build_gossip_fn(plan, mesh, specs))
        txt = fn.lower(key, d).compile().as_text()
        st = analyze(txt)
        counts[path] = st['collectives']['counts']['collective-permute']
        assert any('u8[' in l for l in txt.splitlines()
                   if 'collective-permute(' in l), path
    # 6 leaves x 2 parts x 2 offsets = 24 vs 2 parts x 2 offsets = 4
    assert counts['leaf'] >= 3 * counts['flat'], counts
    print('OK', counts)
    """)
    assert "OK" in out


@pytest.mark.multidevice
def test_trainer_rung_vector_step():
    """select_joint-style per-leaf rung vectors flow through
    Trainer.train_step_for_wire / the PlanBank into ONE mixed flat plan."""
    out = run_in_devices(8, """
    import jax, numpy as np
    from jax.sharding import PartitionSpec
    from repro.compat import make_mesh, set_mesh
    from repro.configs import get_smoke
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.train import make_trainer
    from repro.data import SyntheticLMData
    from repro.adapt import rung_key

    mesh = make_mesh((4, 2), ('data', 'model'))
    arch = get_smoke('qwen3-8b')
    shape = ShapeConfig('t', 64, 8, 'train')
    run = RunConfig(consensus_axis='data', wire='hybrid:block=64,top_j=4',
                    alpha=0.05, optimizer='adam')
    tr = make_trainer(mesh, arch, run, shape)
    n_leaves = len(jax.tree.leaves(
        tr.param_specs(), is_leaf=lambda t: isinstance(t, PartitionSpec)))
    # a mixed rung vector: conservative first half, aggressive second
    specs = tuple('int8:block=64' if i < n_leaves // 2
                  else 'ternary:block=64' for i in range(n_leaves))
    bank = tr.wire_bank(max_size=4)
    step = bank.get(rung_key(specs))
    state = tr.init_state(0)
    data = SyntheticLMData(vocab_size=arch.vocab_size, seq_len=64,
                           global_batch=8, n_nodes=4)
    with set_mesh(mesh):
        state, m = step(state, data.batch(0))
        state, m = step(state, data.batch(1))
    assert np.isfinite(float(m['loss']))
    assert bank.stats()['builds'] == 1
    assert bank.get(rung_key(specs)) is step   # repeated switch = dict hit
    assert bank.stats()['hits'] >= 1
    print('OK', float(m['loss']))
    """, timeout=560)
    assert "OK" in out


# ---------------------------------------------------------------------------
# rung-vector plumbing (single device)
# ---------------------------------------------------------------------------
def test_rung_key_normalization():
    from repro.adapt import rung_key
    assert rung_key("ternary:block=512") == "ternary:block=512"
    assert rung_key(("a", "b", "a")) == ("a", "b", "a")
    # uniform vectors collapse to the shared single-spec plan
    assert rung_key(("a", "a", "a")) == "a"
    class D:  # controller.Decision-alikes
        spec = "x"
    assert rung_key([D(), D()]) == "x"


def test_plan_bank_tuple_keys():
    from repro.adapt.plan_bank import PlanBank
    built = []
    bank = PlanBank(lambda k: built.append(k) or len(built), max_size=4)
    v1 = bank.get(("a", "b"))
    v2 = bank.get(("a", "b"))
    assert v1 == v2 == 1 and bank.stats()["builds"] == 1
    assert bank.get("a") == 2
    assert ("a", "b") in bank and "a" in bank


def test_per_leaf_policy_walks_independently():
    from repro.adapt import PerLeafSNRPolicy
    from repro.adapt.telemetry import TelemetrySnapshot
    ladder = ("dense", "int8:block=256", "ternary:block=512")
    pol = PerLeafSNRPolicy(ladder=ladder, eta_min=1.0, n_leaves=3,
                           margin=1.25, upgrade=2.0, cadence=1,
                           start_index=1)
    assert pol.initial_spec() == ("int8:block=256",) * 3

    def snap(snrs, geo=10.0):
        arr = np.asarray(snrs, np.float64)
        return TelemetrySnapshot(diff_power=arr, noise_power=np.ones_like(arr),
                                 snr=arr, window_diff=arr,
                                 window_noise=np.ones_like(arr), count=5,
                                 geo_snr=geo)

    # leaf0 headroom -> step down; leaf1 in band -> hold; leaf2 low -> climb
    v = pol.decide(1, snap([10.0, 1.5, 1.1]))
    assert v == ("ternary:block=512", "int8:block=256", "dense")
    # aggregate below the floor forces every leaf one rung conservative
    v = pol.decide(2, snap([10.0, 10.0, 10.0], geo=0.5))
    assert v == ("int8:block=256", "dense", "dense")


def test_trainer_plan_for_wire_rung_vector():
    """plan_for_wire accepts a rung vector and records per-leaf formats."""
    from repro.core import gossip as G
    from repro.train.trainer import Trainer
    plan = G.GossipPlan(consensus_axes=("data",), dims=(4,), n_nodes=4,
                        mode="circulant", offsets=(), W=np.eye(4),
                        fmt=make_wire("ternary:block=512"))
    tr = Trainer.__new__(Trainer)
    tr.plan = plan
    tr.consensus_axes = ("data",)
    tr.n_nodes = 4
    specs = ("dense", "ternary:block=512")
    p2 = Trainer.plan_for_wire(tr, specs)
    assert p2.leaf_fmts is not None and len(p2.leaf_fmts) == 2
    assert p2.leaf_fmts[0].name == "dense"
    p3 = Trainer.plan_for_wire(tr, "int8:block=256")
    assert p3.leaf_fmts is None and p3.fmt.name == "int8"
