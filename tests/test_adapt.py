"""repro.adapt: telemetry EMA correctness, closed-form noise oracles,
controller monotonicity + the eta_min floor, plan-bank cache behavior, and
an end-to-end adaptive-vs-static bits comparison on a quadratic problem.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adapt import (ControllerPolicy, FixedPolicy, PlanBank,
                         RateController, SNRFeedbackPolicy, StepDecayPolicy,
                         adaptive_run, bits_to_target, ladder_from_specs)
from repro.adapt import telemetry as tm
from repro.core import consensus as cons, dcdgd, problems
from repro.core.compressors import make_compressor
from repro.core.hybrid_greedy import blocked_plan


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------
class TestTelemetry:
    def test_ema_matches_reference(self):
        decay = 0.8
        rng = np.random.default_rng(0)
        xs = rng.random((10, 3)).astype(np.float32)
        ys = rng.random((10, 3)).astype(np.float32)
        st = tm.init(n_layers=3, window=4)
        ref_d = np.zeros(3)
        ref_n = np.zeros(3)
        for t, (x, y) in enumerate(zip(xs, ys), start=1):
            st = tm.update(st, x, y, decay=decay)
            ref_d = decay * ref_d + (1 - decay) * x
            ref_n = decay * ref_n + (1 - decay) * y
            snap = tm.snapshot(st, decay=decay)
            corr = 1 - decay ** t
            np.testing.assert_allclose(snap.diff_power, ref_d / corr,
                                       rtol=1e-5)
            np.testing.assert_allclose(snap.noise_power, ref_n / corr,
                                       rtol=1e-5)

    def test_bias_correction_unbiased_on_constant_stream(self):
        # constant input: the corrected EMA must equal the input from step 1
        st = tm.init(1, window=4)
        for _ in range(3):
            st = tm.update(st, np.array([5.0]), np.array([2.0]), decay=0.9)
            snap = tm.snapshot(st, decay=0.9)
            assert snap.diff_power[0] == pytest.approx(5.0, rel=1e-5)
            assert snap.snr[0] == pytest.approx(2.5, rel=1e-5)

    def test_ring_window_mean(self):
        st = tm.init(1, window=3)
        for v in (1.0, 2.0, 3.0, 4.0):  # ring keeps the last 3
            st = tm.update(st, np.array([v]), np.array([1.0]))
        snap = tm.snapshot(st)
        assert snap.window_diff[0] == pytest.approx((2 + 3 + 4) / 3)
        assert snap.count == 4

    def test_update_is_jittable(self):
        st = tm.init(2, window=4)
        upd = jax.jit(lambda s, d, n: tm.update(s, d, n, decay=0.9))
        st = upd(st, jnp.ones(2), jnp.ones(2) * 0.5)
        assert int(st.count) == 1
        assert tm.snapshot(st, 0.9).total_snr == pytest.approx(2.0, rel=1e-5)


# ---------------------------------------------------------------------------
# noise oracles + blocked_plan
# ---------------------------------------------------------------------------
class TestNoiseOracles:
    @pytest.mark.parametrize("spec", [
        "sparsifier:p=0.6", "ternary", "blocked_ternary:block=16",
        "lowprec:bits=4", "hybrid:eta=1.5", "blocked_hybrid:block=32,top_j=3",
    ])
    def test_matches_monte_carlo(self, spec):
        comp = make_compressor(spec)
        rng = np.random.default_rng(1)
        z = jnp.asarray(rng.standard_normal(64), jnp.float32)
        pred = float(comp.expected_noise_power(z))
        mc = jax.jit(jax.vmap(lambda k: jnp.sum((comp(k, z) - z) ** 2)))
        emp = float(jnp.mean(mc(jax.random.split(jax.random.PRNGKey(0),
                                                 400))))
        assert emp == pytest.approx(pred, rel=0.15)

    def test_blocked_plan_feasible_and_minimal(self):
        rng = np.random.default_rng(2)
        z = rng.standard_normal(256)
        plan = blocked_plan(z, eta=1.0)
        assert plan is not None
        assert plan.snr >= 1.0
        # a looser target can only get cheaper (or equal)
        loose = blocked_plan(z, eta=0.25)
        assert loose.bits <= plan.bits
        # an unattainable target is reported as infeasible
        assert blocked_plan(z, eta=1e9, blocks=(32,), top_js=(1,)) is None


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------
def _w_ladder():
    return ladder_from_specs(
        ["sparsifier:p=0.8", "lowprec:bits=6", "hybrid:eta=3.3",
         "lowprec:bits=4", "blocked_ternary:block=16", "ternary"])


class TestController:
    def test_for_topology_requires_guaranteed_anchor(self):
        bad = ladder_from_specs(["ternary", "blocked_ternary:block=16"])
        with pytest.raises(ValueError, match="DC-DGD convergence"):
            RateController.for_topology(cons.W1_PAPER, bad)

    def test_for_topology_anchor_checked_at_real_dimension(self):
        # LowPrecision's bound is 4 levels^2 / d: at d=1 lowprec:bits=2
        # clears the W1 bar (4.0 > 2.62) but at d=512 it is ~0.008 — the
        # anchor check must use the caller's dimension, not d=1
        ladder = ladder_from_specs(["lowprec:bits=2"])
        RateController.for_topology(cons.W1_PAPER, ladder, dim=1)  # passes
        with pytest.raises(ValueError, match="DC-DGD convergence"):
            RateController.for_topology(cons.W1_PAPER, ladder, dim=512)

    def test_monotone_bits_in_measured_snr(self):
        """More measured headroom => never MORE wire bits; and the floor:
        every decision's SNR clears eta_min."""
        ctl = RateController.for_topology(cons.W1_PAPER, _w_ladder())
        rng = np.random.default_rng(3)
        base = rng.standard_normal(512)
        # sparsify progressively: fewer significant coords => every rung's
        # measured SNR rises (more compressible differential)
        bits_seq, snr_seq = [], []
        for keep in (512, 256, 64, 16, 4):
            z = np.zeros(512)
            z[:keep] = base[:keep] * 10
            z += base * 0.001   # tiny dense floor
            dec = ctl.select(z)
            bits_seq.append(dec.bits / 1.0)
            snr_seq.append(dec.predicted_snr)
            assert max(dec.predicted_snr, dec.guaranteed_snr) > ctl.eta_min
        assert all(b2 <= b1 * 1.0 + 1e-9
                   for b1, b2 in zip(bits_seq, bits_seq[1:])), bits_seq

    def test_degenerate_sample_has_infinite_snr(self):
        ctl = RateController.for_topology(cons.W1_PAPER, _w_ladder())
        dec = ctl.select(np.zeros(512))   # zero differential: zero noise
        assert dec.predicted_snr == np.inf
        assert max(dec.predicted_snr, dec.guaranteed_snr) > ctl.eta_min

    def test_synthesized_hybrid_rung_from_blocked_plan(self):
        """With only a conservative anchor on the ladder, the blocked_plan
        inner oracle synthesizes a tuned (block, top_j) hybrid rung that
        wins on a heavy-tailed differential."""
        ctl = RateController.for_topology(
            cons.W1_PAPER, ladder_from_specs(["sparsifier:p=0.8"]))
        rng = np.random.default_rng(5)
        z = np.concatenate([rng.standard_normal(8) * 100,
                            rng.standard_normal(504) * 0.01])
        dec = ctl.select(z)
        assert dec.spec.startswith("blocked_hybrid:"), dec
        assert dec.predicted_snr >= ctl.bar
        # and the synthesized spec is buildable by the math-level registry
        assert make_compressor(dec.spec).name == "blocked_hybrid"

    def test_fallback_retreats_to_max_snr_rung(self):
        # construct directly (for_topology would reject this ladder): only
        # data-dependent rungs, none clears the W1 bar on a gaussian sample
        ctl = RateController(
            ladder=ladder_from_specs(["blocked_ternary:block=16", "ternary"]),
            eta_min=cons.spectrum(cons.W1_PAPER).snr_threshold,
            synthesize_hybrid=False)
        z = np.random.default_rng(0).standard_normal(512)
        dec = ctl.select(z)
        assert dec.reason == "fallback"
        # picks the higher-SNR (more conservative) of the two rungs
        assert dec.spec == "blocked_ternary:block=16"

    def test_select_joint_respects_aggregate_and_floor(self):
        ctl = RateController.for_topology(cons.W1_PAPER, _w_ladder())
        rng = np.random.default_rng(4)
        probes = [rng.standard_normal(256), rng.standard_normal(256) * 0.01,
                  np.concatenate([rng.standard_normal(8) * 50,
                                  rng.standard_normal(248) * 0.01])]
        decs = ctl.select_joint(probes)
        assert len(decs) == 3
        powers = [float((np.asarray(z) ** 2).sum()) for z in probes]
        noises = [p / d.predicted_snr if np.isfinite(d.predicted_snr)
                  else 0.0 for p, d in zip(powers, decs)]
        agg = sum(powers) / max(sum(noises), 1e-30)
        assert agg > ctl.eta_min
        for d in decs:
            assert max(d.predicted_snr, d.guaranteed_snr) > ctl.eta_min


# ---------------------------------------------------------------------------
# plan bank
# ---------------------------------------------------------------------------
class TestPlanBank:
    def test_repeated_switch_is_cache_hit(self):
        built = []
        bank = PlanBank(lambda spec: built.append(spec) or f"plan[{spec}]",
                        max_size=4)
        seq = ["a", "b", "a", "b", "a", "b", "b", "a"]
        for s in seq:
            assert bank.get(s) == f"plan[{s}]"
        assert bank.builds == 2          # one build per distinct spec
        assert bank.hits == len(seq) - 2
        assert built == ["a", "b"]

    def test_lru_eviction_bounded(self):
        bank = PlanBank(lambda s: s, max_size=2)
        for s in ("a", "b", "c"):
            bank.get(s)
        assert len(bank) == 2
        assert "a" not in bank and "c" in bank
        assert bank.evictions == 1

    def test_no_recompile_on_jitted_steps(self):
        """Repeated wire switches in adaptive_run reuse the jitted step:
        builds == number of DISTINCT rungs ever activated."""
        prob = problems.quadratic(n_nodes=4, dim=16, seed=1)
        W = cons.metropolis_weights(cons.ring_adjacency(4), lazy=0.3)
        r = adaptive_run(prob, W, ["sparsifier:p=0.9", "sparsifier:p=0.7"],
                         0.05, 30, jax.random.PRNGKey(0), cadence=5)
        distinct = len(set(r["spec_per_step"]))
        assert r["bank_stats"]["builds"] == distinct
        assert r["bank_stats"]["hits"] == 30 - distinct


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
class TestPolicies:
    def test_fixed_never_switches(self):
        p = FixedPolicy("ternary")
        assert p.initial_spec() == "ternary"
        assert p.decide(100, None) is None

    def test_step_decay_schedule(self):
        p = StepDecayPolicy(((0, "a"), (10, "b"), (20, "c")))
        assert p.initial_spec() == "a"
        assert p.decide(9, None) == "a"
        assert p.decide(10, None) == "b"
        assert p.decide(25, None) == "c"

    def test_snr_feedback_hysteresis(self):
        pol = SNRFeedbackPolicy(ladder=("safe", "mid", "cheap"),
                                eta_min=1.0, margin=1.2, upgrade=2.0,
                                cadence=1, start_index=1)

        def snap(snr):
            arr = np.array([snr])
            one = np.array([1.0])
            return tm.TelemetrySnapshot(diff_power=arr, noise_power=one,
                                        snr=arr, window_diff=arr,
                                        window_noise=one, count=5)
        # ample headroom: step down toward cheap
        assert pol.decide(1, snap(10.0)) == "cheap"
        # inside the hysteresis band: hold
        assert pol.decide(2, snap(1.5)) == "cheap"
        # below the bar but above eta_min: climb one rung
        assert pol.decide(3, snap(1.1)) == "mid"
        # below eta_min: emergency climb fires even off-cadence
        pol.cadence = 100
        assert pol.decide(4, snap(0.5)) == "safe"


# ---------------------------------------------------------------------------
# end-to-end
# ---------------------------------------------------------------------------
class TestEndToEnd:
    def test_adaptive_matches_static_hybrid_loss_with_fewer_bits(self):
        """Adaptive DC-DGD reaches the static-hybrid target loss with fewer
        cumulative wire bits on a quadratic problem (ISSUE acceptance)."""
        prob = problems.quadratic(n_nodes=5, dim=96, seed=3)
        W = cons.W1_PAPER
        steps = 80
        static = dcdgd.run(prob, W, make_compressor("hybrid:eta=3.3"),
                           0.05, steps, jax.random.PRNGKey(0))
        ladder = ["sparsifier:p=0.8", "hybrid:eta=3.3", "lowprec:bits=5",
                  "lowprec:bits=4", "ternary"]
        adaptive = adaptive_run(prob, W, ladder, 0.05, steps,
                                jax.random.PRNGKey(0), cadence=10)
        g0 = float(static["f_bar"][0] - prob.f_star)
        target = 0.05 * g0
        b_static = bits_to_target(static, target, f_star=prob.f_star)
        b_adapt = bits_to_target(adaptive, target, f_star=prob.f_star)
        assert b_static is not None and b_adapt is not None
        assert b_adapt < b_static, (b_adapt, b_static)
        # the controller never selected below the Theorem-1 floor
        eta_min = cons.spectrum(W).snr_threshold
        assert all(max(d.predicted_snr, d.guaranteed_snr) > eta_min
                   for d in adaptive["decisions"])
        # and the final loss is no worse than static hybrid's
        assert adaptive["f_bar"][-1] <= static["f_bar"][-1] * 1.05
