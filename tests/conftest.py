"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device tests spawn subprocesses (helpers below)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_in_devices(n_devices: int, code: str, timeout: int = 420) -> str:
    """Run a python snippet in a subprocess with n virtual CPU devices.
    The snippet should print its assertions' evidence; raises on nonzero."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC)
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def devices8():
    return lambda code, timeout=420: run_in_devices(8, code, timeout)
