"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device tests spawn subprocesses (helpers below)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"

# Property tests must be DETERMINISTIC inside tier-1: register a bounded,
# derandomized hypothesis profile (scripts/run_tests.sh --hypothesis
# additionally pins --hypothesis-seed=0; set HYPOTHESIS_PROFILE=dev for an
# exploratory randomized run).  Guarded: without hypothesis installed the
# property tests degrade to their seeded fallbacks.
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro-ci", max_examples=20, deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("dev", max_examples=50, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "repro-ci"))
except ImportError:
    pass


def run_in_devices(n_devices: int, code: str, timeout: int = 420) -> str:
    """Run a python snippet in a subprocess with n virtual CPU devices.
    The snippet should print its assertions' evidence; raises on nonzero."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC)
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def devices8():
    return lambda code, timeout=420: run_in_devices(8, code, timeout)
