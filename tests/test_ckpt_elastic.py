"""Checkpoint/restart + elastic membership tests."""
import dataclasses
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, latest_step, restore, save
from repro.core import consensus as cons
from repro.core.compressors import Sparsifier
from repro.core import dcdgd, problems
from repro.runtime.elastic import Membership, apply_state_plan, \
    rebuild_consensus


class TestCheckpoint:
    def _state(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"x": {"w": jax.random.normal(k, (4, 8, 3)),
                      "b": jnp.zeros((4, 3))},
                "s": {"w": jax.random.normal(k, (4, 8, 3)) * 0.1,
                      "b": jnp.zeros((4, 3))},
                "step": jnp.int32(7)}

    def test_save_restore_roundtrip(self, tmp_path):
        st = self._state()
        save(tmp_path, 7, st)
        assert latest_step(tmp_path) == 7
        back, manifest = restore(tmp_path, 7, jax.eval_shape(lambda: st))
        assert manifest["step"] == 7
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_no_partial_visible(self, tmp_path):
        st = self._state()
        save(tmp_path, 1, st)
        # orphaned tmp dirs are invisible to latest_step
        (tmp_path / "step_00000002.tmp-zzz").mkdir()
        assert latest_step(tmp_path) == 1

    def test_retention(self, tmp_path):
        st = self._state()
        for s in (1, 2, 3, 4, 5):
            save(tmp_path, s, st, retain=2)
        steps = sorted(p.name for p in Path(tmp_path).glob("step_*"))
        assert len(steps) == 2 and steps[-1].endswith("5")

    def test_manager_resume(self, tmp_path):
        st = self._state()
        mgr = CheckpointManager(str(tmp_path), every=2)
        assert mgr.maybe_save(1, st) is None
        assert mgr.maybe_save(2, st) is not None
        back, manifest = mgr.resume(jax.eval_shape(lambda: st))
        assert manifest["step"] == 2

    def test_elastic_reshard_restore(self, tmp_path):
        """4-node checkpoint restores into a 6-node trainer: x leaves become
        the consensus mean, s leaves zero."""
        st = self._state()
        save(tmp_path, 3, st)
        target = {"x": {"w": jax.ShapeDtypeStruct((6, 8, 3), jnp.float32),
                        "b": jax.ShapeDtypeStruct((6, 3), jnp.float32)},
                  "s": {"w": jax.ShapeDtypeStruct((6, 8, 3), jnp.float32),
                        "b": jax.ShapeDtypeStruct((6, 3), jnp.float32)},
                  "step": jax.ShapeDtypeStruct((), jnp.int32)}
        back, _ = restore(tmp_path, 3, target, n_nodes_from=4, n_nodes_to=6)
        mean = np.asarray(st["x"]["w"]).mean(0)
        for row in np.asarray(back["x"]["w"]):
            np.testing.assert_allclose(row, mean, rtol=1e-6)
        assert np.abs(np.asarray(back["s"]["w"])).max() == 0


class TestElastic:
    def test_membership_rebuild_keeps_double_stochastic(self):
        m = Membership(node_ids=list(range(8)), topology="ring")
        cons.validate_consensus_matrix(m.W)
        plan = m.leave(3)
        cons.validate_consensus_matrix(m.W)
        assert m.n == 7 and plan["keep_rows"] == [0, 1, 2, 4, 5, 6, 7]
        plan = m.join(99)
        cons.validate_consensus_matrix(m.W)
        assert m.n == 8 and plan["init_from"] == 6

    def test_thresholds_recomputed(self):
        m = Membership(node_ids=list(range(10)), topology="ring")
        info = rebuild_consensus(m, snr_lb=4.0)
        assert info["ok"] and "eta_min" in info
        # a sparse ring of 10 has a mild threshold; complete graph milder
        m2 = Membership(node_ids=list(range(10)), topology="complete")
        info2 = rebuild_consensus(m2, snr_lb=4.0)
        assert info2["eta_min"] <= info["eta_min"] + 1e-9

    def test_join_leave_convergence_cycle(self):
        """Full cycle on a quadratic: converge with 4 nodes, node joins
        (copy-neighbor init), keeps converging; node leaves, still OK.
        Constant-step DC-DGD converges to an error ball (Thm. 3), so the
        assertions are RELATIVE improvements over the start point."""
        prob4 = problems.quadratic(n_nodes=4, dim=6, seed=1)
        comp = Sparsifier(p=0.8)
        m = Membership(node_ids=[0, 1, 2, 3], topology="ring")
        x = jnp.zeros((4, 6))
        s = jnp.zeros((4, 6))
        key = jax.random.PRNGKey(0)

        def steps(prob, W, x, s, key, n_iter, alpha=0.02):
            Wj = jnp.asarray(W, jnp.float32)
            for _ in range(n_iter):
                g = prob.grad(x)
                d = s - alpha * g
                key, sub = jax.random.split(key)
                c = dcdgd._node_compress(comp, sub, d)
                x = x + c
                s = s + dcdgd._mix(Wj, c) - c
            return x, s, key

        def gsq(prob, x):
            return float(jnp.sum(prob.global_grad(jnp.mean(x, 0)) ** 2))

        g0 = gsq(prob4, x)
        x, s, key = steps(prob4, m.W, x, s, key, 300)

        plan = m.join(4)
        prob5 = problems.quadratic(n_nodes=5, dim=6, seed=1)
        x, s = apply_state_plan(x, s, plan)
        assert x.shape[0] == 5
        g5_start = gsq(prob5, x)
        x, s, key = steps(prob5, m.W, x, s, key, 400)
        g5 = gsq(prob5, x)

        plan = m.leave(2)
        x, s = apply_state_plan(x, s, plan)
        prob4b = problems.quadratic(n_nodes=4, dim=6, seed=1)
        g4_start = gsq(prob4b, x)   # the objective CHANGED with the node set
        x, s, key = steps(prob4b, m.W, x, s, key, 400)
        g4 = gsq(prob4b, x)
        # big relative improvement after each membership change
        assert g5 < 0.2 * max(g5_start, 1e-9) + 0.05 * g0, (g5, g5_start, g0)
        assert g4 < 0.25 * max(g4_start, 1e-9) + 0.05 * g0, (g4, g4_start, g0)

    def test_topology_degradation_breaks_theorem1_gate(self):
        """A compressor tuned to a dense graph violates the threshold when
        the graph thins (link failures) — the gate must catch it.
        (A Metropolis ring's lambda_N is -1/3 for any n, so pure GROWTH
        keeps the threshold constant; the dangerous transition is density.)"""
        m = Membership(node_ids=list(range(8)), topology="complete", lazy=0.0)
        snr = 1.1 * m.spectrum.snr_threshold   # tuned to the dense graph
        assert rebuild_consensus(m, snr)["ok"]
        m.topology = "ring"                     # links degraded to a ring
        m._rebuild()
        assert m.spectrum.snr_threshold > snr
        with pytest.raises(RuntimeError):
            rebuild_consensus(m, snr)
