"""The repro.topology front door: TopoSpec grammar round-trips and
canonical idempotence, Topology spectral quantities vs direct eigvalsh on
every constructor, circulant-embeddability detection vs the dense
fallback (with gossip parity on both lowerings), tagged PerLeafPlan keys,
FaultComm composition, and the eta_min retarget across a mid-run
topology switch (zero Theorem-1 violations)."""
import dataclasses
import json

import numpy as np
import pytest

from repro.comm import (Compose, FaultComm, PerLeafPlan, RateComm,
                        StaticComm, StepTelemetry)
from repro.core import consensus as cons
from repro.runtime.elastic import Membership
from repro.topology import (TopoSchedule, TopoSpec, Topology, TopologyComm,
                            topology)

from conftest import run_in_devices

# every spec shape the grammar ships
REPO_TOPOS = [
    "ring", "ring:hops=2", "torus:4x2", "torus", "complete", "star",
    "erdos:p=0.3,seed=0", "erdos:p=0.5", "expander:d=4",
    "expander:d=4,seed=3", "ring:hops=2,lazy=0.25", "torus:4x2,lazy=0.5",
    "w1", "w2", "fig3a", "fig3b",
]


# ---------------------------------------------------------------------------
# grammar
# ---------------------------------------------------------------------------
class TestTopoSpec:
    @pytest.mark.parametrize("spec", REPO_TOPOS)
    def test_parse_canonical_roundtrip_idempotent(self, spec):
        t = TopoSpec.parse(spec)
        assert t.canonical() == spec                  # repo specs ARE canonical
        assert TopoSpec.parse(t.canonical()) == t     # parse . canonical = id
        assert TopoSpec.parse(t) is t                 # idempotent on TopoSpec
        assert hash(TopoSpec.parse(spec)) == hash(t)  # hashable key

    def test_canonical_sorts_args_and_leads_dims(self):
        a = TopoSpec.parse("erdos:seed=2,p=0.4")
        b = TopoSpec.parse("erdos:p=0.4,seed=2")
        assert a == b and a.canonical() == "erdos:p=0.4,seed=2"
        t = TopoSpec.parse("torus:4x2,lazy=0.5")
        assert t.dims == (4, 2) and t.canonical() == "torus:4x2,lazy=0.5"

    @pytest.mark.parametrize("bad", [
        "ringg", "ring:hops", "ring:hops=2,hops=3", "torus:4y2",
        "erdos", "erdos:p=0.3,q=1", "expander", "star:d=3",
        "w1:lazy=0.5", "ring:hops=two", "file:"])
    def test_malformed_specs_rejected_at_parse(self, bad):
        with pytest.raises(ValueError):
            TopoSpec.parse(bad)

    def test_fixed_n(self):
        assert TopoSpec.parse("w1").fixed_n == 5
        assert TopoSpec.parse("fig3b").fixed_n == 10
        assert TopoSpec.parse("torus:4x2").fixed_n == 8
        assert TopoSpec.parse("ring").fixed_n is None
        with pytest.raises(ValueError):
            Topology.from_spec("w1", n=7)
        with pytest.raises(ValueError):
            Topology.from_spec("ring")        # n required

    def test_typed_configs_fail_at_build(self):
        from repro.configs.base import AdaptConfig, RunConfig
        with pytest.raises(ValueError):
            RunConfig(topology="ringg")
        with pytest.raises(ValueError):
            AdaptConfig(topo_schedule=((0, "torus:4y2"),))
        with pytest.raises(ValueError):
            AdaptConfig(ladder=("dense", "ternaryy"))
        rc = RunConfig(topology="torus:4x2")
        assert isinstance(rc.topology, TopoSpec)
        ac = AdaptConfig(topo_schedule=((5, "torus:4x2"), (0, "ring")))
        assert [s for s, _ in ac.topo_schedule] == [0, 5]   # sorted
        assert all(isinstance(sp, TopoSpec) for _, sp in ac.topo_schedule)


# ---------------------------------------------------------------------------
# spectra vs direct eigendecomposition, every constructor
# ---------------------------------------------------------------------------
SPEC_N = [("ring", 8), ("ring:hops=2", 9), ("torus:4x2", None),
          ("torus", 12), ("complete", 6), ("star", 6),
          ("erdos:p=0.5,seed=1", 10), ("expander:d=4,seed=0", 12),
          ("w1", None), ("w2", None), ("fig3a", None), ("fig3b", None)]


class TestTopologySpectra:
    @pytest.mark.parametrize("spec,n", SPEC_N)
    def test_spectral_quantities_match_eigvalsh(self, spec, n):
        t = topology(spec, n=n, lazy=0.25)
        cons.validate_consensus_matrix(t.W)
        lam = np.sort(np.linalg.eigvalsh(t.W))
        lam_n, lam_2 = float(lam[0]), float(lam[-2])
        assert t.lambda_n == pytest.approx(lam_n, abs=1e-12)
        assert t.lambda_2 == pytest.approx(lam_2, abs=1e-12)
        assert t.beta == pytest.approx(max(abs(lam_2), abs(lam_n)), abs=1e-12)
        assert t.eta_min == pytest.approx((1 - lam_n) / (1 + lam_n),
                                          rel=1e-12)
        # alpha_max matches the Theorem-1 closed form
        eta, L = 2.0 * t.eta_min, 3.0
        assert t.alpha_max(eta, L) == pytest.approx(
            (lam_n * (eta + 1) + eta - 1) / (L * (1 + eta)), rel=1e-12)

    def test_paper_matrices_exact(self):
        np.testing.assert_allclose(topology("w1").W, cons.W1_PAPER)
        np.testing.assert_allclose(topology("w2").W, cons.W2_PAPER)
        np.testing.assert_allclose(topology("fig3a").W,
                                   cons.fig3_topology_a())
        np.testing.assert_allclose(topology("fig3b").W,
                                   cons.fig3_topology_b())

    def test_spec_lazy_wins_over_default(self):
        a = topology("ring:lazy=0.5", n=8, lazy=0.0)
        b = topology("ring", n=8, lazy=0.5)
        np.testing.assert_allclose(a.W, b.W)

    def test_file_backed(self, tmp_path):
        adj = np.asarray(cons.ring_adjacency(6))
        npy = tmp_path / "g.npy"
        np.save(npy, adj)
        t = topology(f"file:{npy}")
        np.testing.assert_allclose(t.W, cons.metropolis_weights(adj))
        js = tmp_path / "g.json"
        js.write_text(json.dumps(
            {"n": 6, "edges": [[i, (i + 1) % 6] for i in range(6)]}))
        t2 = topology(f"file:{js}")
        np.testing.assert_allclose(t2.W, t.W)
        assert t2.canonical() == f"file:{js}"

    def test_disconnected_rejected(self, tmp_path):
        adj = np.zeros((4, 4), bool)
        adj[0, 1] = adj[1, 0] = adj[2, 3] = adj[3, 2] = True
        np.save(tmp_path / "bad.npy", adj)
        with pytest.raises(ValueError):
            topology(f"file:{tmp_path / 'bad.npy'}")

    def test_mesh_consensus_matrix_shim_parity(self):
        from repro.core.gossip import mesh_consensus_matrix
        W = mesh_consensus_matrix((2, 4), "ring", lazy=0.25)
        np.testing.assert_allclose(W, cons.torus_consensus(2, 4, lazy=0.25))
        np.testing.assert_allclose(mesh_consensus_matrix((2,), "ring"),
                                   [[0.75, 0.25], [0.25, 0.75]])

    def test_ring_with_args_not_promoted_on_2d_mesh(self):
        # a bare ring promotes to the mesh torus (legacy dispatch), but a
        # ring with explicit hops must build the graph the spec names —
        # the torus cannot honor hops=2
        t = Topology.for_mesh_dims((4, 2), "ring:hops=2")
        assert t.spec.name == "ring" and t.degree == 4
        np.testing.assert_allclose(
            t.W, cons.metropolis_weights(cons.ring_adjacency(8, hops=2),
                                         lazy=0.25))
        assert Topology.for_mesh_dims((4, 2), "ring").spec.name == "torus"
        assert Topology.for_mesh_dims(
            (4, 2), "ring:lazy=0.5").spec.name == "torus"

    def test_drop_renormalize_dense_matches_offset_rule(self):
        from repro.runtime.fault import drop_renormalize_dense, peel_plan_key
        W = topology("ring", n=6, lazy=0.25).W
        W2 = drop_renormalize_dense(W, [0])
        cons.validate_consensus_matrix(W2)
        assert W2[0, 1] == 0 and W2[1, 0] == 0       # edge (0,1) out
        assert W2[0, 0] > W[0, 0] and W2[1, 1] > W[1, 1]
        assert peel_plan_key(("topo", "ring", ("fault", (0,), "dense"))) \
            == ("ring", (0,), "dense")
        assert peel_plan_key("dense") == (None, (), "dense")


# ---------------------------------------------------------------------------
# circulant embeddability vs dense fallback
# ---------------------------------------------------------------------------
class TestLowering:
    @pytest.mark.parametrize("spec,n,dims", [
        ("ring", 8, (8,)), ("ring:hops=2", 8, (8,)),
        ("torus:4x2", None, (4, 2)), ("expander:d=4,seed=0", 12, (12,)),
        ("complete", 6, (6,))])
    def test_circulant_detected_and_exact(self, spec, n, dims):
        t = topology(spec, n=n, lazy=0.25)
        mode, offs = t.lowering(dims)
        assert mode == "circulant" and offs
        # applying the offsets reproduces W @ x exactly
        rng = np.random.default_rng(0)
        x = rng.standard_normal(t.n)
        y = np.zeros_like(x)
        lin = np.arange(t.n).reshape(dims)
        for off, w in offs:
            src = np.roll(lin, shift=[-o for o in off],
                          axis=tuple(range(len(dims)))).reshape(-1)
            y += w * x[src]
        np.testing.assert_allclose(y, t.W @ x, atol=1e-12)
        assert t.n_out(dims) == sum(1 for off, _ in offs
                                    if any(o != 0 for o in off))

    @pytest.mark.parametrize("spec,n,dims", [
        ("star", 6, (6,)), ("erdos:p=0.5,seed=1", 10, (10,)),
        ("fig3a", None, (10,)), ("fig3b", None, (10,)),
        ("torus:4x2", None, (8,)),      # torus graph, linear mesh: dense
        ("ring", 8, (2, 4))])           # ring graph, torus group: dense
    def test_dense_fallback(self, spec, n, dims):
        t = topology(spec, n=n)
        mode, offs = t.lowering(dims)
        assert mode == "dense" and offs == ()
        assert t.n_out(dims) == t.degree

    def test_dims_must_match_n(self):
        with pytest.raises(ValueError):
            topology("ring", n=8).lowering((4,))


# ---------------------------------------------------------------------------
# tagged plan keys + FaultComm composition
# ---------------------------------------------------------------------------
class TestTaggedPlans:
    def test_topo_and_fault_key_forms(self):
        p = PerLeafPlan.uniform("dense")
        assert p.key() == "dense"
        assert dataclasses.replace(p, topo="torus:4x2").key() == \
            ("topo", "torus:4x2", "dense")
        assert dataclasses.replace(p, drops=(1, 0, 1)).key() == \
            ("fault", (0, 1), "dense")
        both = dataclasses.replace(p, topo="ring", drops=(2,))
        assert both.key() == ("topo", "ring", ("fault", (2,), "dense"))
        # outage is one shared entry regardless of tags
        from repro.comm import OUTAGE_PLAN
        assert dataclasses.replace(OUTAGE_PLAN, topo="ring").key() == "outage"

    def test_fault_comm_rides_drops_on_final_plan(self):
        class Sim:
            def dropped(self, step, n_classes):
                return {1: [0], 2: [0, 1]}.get(step, [])
        comp = Compose(StaticComm("dense"), FaultComm(sim=Sim(), n_classes=2))
        assert comp.decide(0).key() == "dense"
        assert comp.decide(1).key() == ("fault", (0,), "dense")
        assert comp.decide(2).outage          # every class out = blackout
        assert comp.decide(3).key() == "dense"

    def test_fault_comm_on_topology_rederives_class_count(self):
        # the stale-edge-space bug: under a composed TopologyComm the
        # droppable-class count must follow the ACTIVE graph
        class Sim:
            def dropped(self, step, n_classes):
                return [n_classes - 1]

        def edges(canonical):
            W = np.asarray(topology(canonical, n=8).W)
            off = np.abs(W) > 1e-12
            np.fill_diagonal(off, False)
            return int(off.sum()) // 2

        fc = FaultComm(sim=Sim(), n_classes=edges("ring"),
                       n_classes_fn=edges)
        assert fc.n_classes == 8                      # ring-8: 8 edges
        fc.on_topology(TopoSpec.parse("torus:4x2").canonical())
        assert fc.n_classes == 12                     # torus 4x2: 12 edges
        assert fc.drops_at(0) == (11,)                # NEW edge space
        # without n_classes_fn the hook is a no-op (legacy behavior)
        fc2 = FaultComm(sim=Sim(), n_classes=8)
        fc2.on_topology("torus:4x2")
        assert fc2.n_classes == 8

    def test_topology_switch_drives_fault_comm_hook(self):
        # TopologyComm.maybe_switch calls on_topology on every member:
        # complete-8 (28 edges) -> ring-8 (8 edges) at step 5
        class Sim:
            def dropped(self, step, n_classes):
                return []

        def edges(canonical):
            W = np.asarray(topology(canonical, n=8).W)
            off = np.abs(W) > 1e-12
            np.fill_diagonal(off, False)
            return int(off.sum()) // 2

        tc = _topo_comm(switch_step=5)
        fc = FaultComm(sim=Sim(), n_classes=edges("complete:lazy=0.0"),
                       n_classes_fn=edges)
        assert fc.n_classes == 28
        assert not tc.maybe_switch(4, (fc, tc))
        assert fc.n_classes == 28
        assert tc.maybe_switch(5, (fc, tc))
        assert fc.n_classes == 8

    def test_fault_plan_keeps_w_doubly_stochastic(self):
        from repro.runtime.fault import fault_plan, non_self_classes
        t = topology("ring", n=8, lazy=0.25)
        _, offs = t.lowering((8,))
        from repro.core.gossip import GossipPlan
        from repro.core.wire import DenseWire
        gp = GossipPlan(consensus_axes=("data",), dims=(8,), n_nodes=8,
                        mode="circulant", offsets=offs, W=t.W,
                        fmt=DenseWire())
        nz = non_self_classes(gp)
        eff = fault_plan(gp, [0])
        W_eff = np.zeros((8, 8))
        for off, w in eff.offsets:
            for i in range(8):
                W_eff[(i + off[0]) % 8, i] += w
        assert np.allclose(W_eff.sum(0), 1) and np.allclose(W_eff.sum(1), 1)
        assert np.allclose(W_eff, W_eff.T)
        assert eff.n_out == gp.n_out - 2      # both directions dropped
        assert len(nz) == 2


# ---------------------------------------------------------------------------
# schedule + retarget: zero Theorem-1 violations across a mid-run switch
# ---------------------------------------------------------------------------
LADDER = ("dense", "int8:block=64", "ternary:block=64")


def _topo_comm(switch_step=5, guaranteed=True):
    from repro.core.wire import make_wire
    sched = TopoSchedule.parse(f"{switch_step}:ring:lazy=0.0",
                               opening="complete:lazy=0.0")
    topos = {sp.canonical(): topology(sp, n=8) for sp in sched.specs()}
    return TopologyComm(
        schedule=sched, topologies=topos, dims=(8,),
        guaranteed_snr=(lambda s: make_wire(s).snr_lower_bound(1))
        if guaranteed else None)


def _tel(step, snr):
    d = np.full((1,), 100.0)
    return StepTelemetry(step=step, diff_power=d, noise_power=d / snr)


class TestRetarget:
    def test_floors(self):
        # complete (lazy 0): lambda_N = 0 -> eta_min = 1; ring of 8
        # (lazy 0): lambda_N = -1/3 -> eta_min = 2 — the switch RAISES the bar
        assert topology("complete:lazy=0.0", n=8).eta_min == \
            pytest.approx(1.0, abs=1e-9)
        assert topology("ring:lazy=0.0", n=8).eta_min == \
            pytest.approx(2.0, abs=1e-9)

    def test_switch_retargets_rate_member_zero_violations(self):
        from repro.adapt import SNRFeedbackPolicy
        tc = _topo_comm(switch_step=5)
        rate = RateComm(policy=SNRFeedbackPolicy(
            ladder=LADDER, eta_min=tc.active.eta_min, margin=1.0,
            upgrade=1e9, cadence=1, start_index=2), n_leaves=1, cadence=1)
        comp = Compose(rate, tc)
        keys = []
        for step in range(10):
            plan = comp.decide(step)
            keys.append(plan.key())
            # measured SNR 1.5: above the complete-graph floor (1.0),
            # below the ring floor (2.0)
            comp.observe(_tel(step, snr=1.5))
        # before the switch: the aggressive rung holds on the old graph
        assert keys[4] == ("topo", "complete:lazy=0.0", "ternary:block=64")
        # the switch pushed the new floor into the wrapped policy...
        assert rate.policy.eta_min == pytest.approx(2.0, abs=1e-9)
        assert [s for s, old, new, _ in tc.switch_log] == [5]
        # ...and the emergency climb walked to the guaranteed-safe anchor
        assert keys[-1] == ("topo", "ring:lazy=0.0", "dense")
        # a reacting policy sustains no below-floor operation
        assert tc.violations == 0

    def test_stale_policy_is_audited_as_violations(self):
        # a proposer that ignores the floor entirely (StaticComm) holds a
        # no-guarantee rung below the new floor -> sustained violations
        tc = _topo_comm(switch_step=2)
        comp = Compose(StaticComm("ternary:block=64"), tc)
        for step in range(8):
            comp.decide(step)
            comp.observe(_tel(step, snr=1.5))
        assert tc.violations > 0

    def test_budget_member_retargets_neighbors_and_floor(self):
        from repro.adapt import (BudgetController, BudgetPolicy,
                                 BudgetSchedule, ladder_from_specs)
        from repro.comm import BudgetComm
        ctl = BudgetController(
            ladder=ladder_from_specs(LADDER, level="wire"),
            shapes=((64,),), neighbors=2, eta_min=1.0)
        bc = BudgetComm(policy=BudgetPolicy(
            controller=ctl, schedule=BudgetSchedule(bits=1e12), cadence=1))
        cost2 = bc.plan_cost(PerLeafPlan.uniform("dense"))
        bc.retarget(eta_min=2.0, neighbors=4)
        assert ctl.eta_min == 2.0 and ctl.neighbors == 4
        assert bc.plan_cost(PerLeafPlan.uniform("dense")) == \
            pytest.approx(2 * cost2)

    def test_schedule_parse_and_membership_front_door(self):
        s = TopoSchedule.parse("4:torus:4x2", opening="ring")
        assert s.active_at(3).canonical() == "ring"
        assert s.active_at(4).canonical() == "torus:4x2"
        with pytest.raises(AssertionError):
            TopoSchedule(entries=((3, TopoSpec.parse("ring")),))
        # duplicate steps get the designed message, not a sort TypeError
        with pytest.raises(AssertionError, match="duplicate"):
            TopoSchedule.parse("3:ring;3:torus:4x2", opening="complete")
        from repro.configs.base import AdaptConfig
        AdaptConfig(topo_schedule=((3, "ring"), (3, "complete")))  # sortable
        m = Membership(node_ids=list(range(10)),
                       topology="erdos:p=0.6,seed=1")
        cons.validate_consensus_matrix(m.W)
        assert m.topo.spec.name == "erdos"
        m2 = Membership(node_ids=[0, 1], topology="ring")
        assert m2.topo.spec.name == "complete"     # tiny n densifies


# ---------------------------------------------------------------------------
# multidevice: bit-exact gossip parity on both lowerings, and the composed
# trainer session across a scheduled switch (no recompiles beyond the bank)
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_gossip_parity_circulant_vs_dense_lowering():
    out = run_in_devices(8, """
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh
        from jax.sharding import PartitionSpec as P
        from repro.core.wire import make_wire
        from repro.core.gossip import make_plan, build_gossip_fn
        mesh = make_mesh((8,), ("data",))
        fmt = make_wire("hybrid:block=64,top_j=2")
        plan = make_plan(mesh, ("data",), fmt, topology="ring:hops=2")
        assert plan.mode == "circulant" and plan.topo is not None
        assert plan.topo.canonical() == "ring:hops=2"
        dense = dataclasses.replace(plan, mode="dense", offsets=())
        key = jax.random.PRNGKey(0)
        d = {"a": jax.random.normal(key, (8, 5, 128)),
             "b": jax.random.normal(key, (8, 64))}
        specs = {"a": P("data", None, None), "b": P("data", None)}
        c1, a1 = jax.jit(build_gossip_fn(plan, mesh, specs))(key, d)
        c2, a2 = jax.jit(build_gossip_fn(dense, mesh, specs))(key, d)
        for k in d:
            # the DECODE is bit-exact across lowerings (same wire bytes)
            assert (np.asarray(c1[k]) == np.asarray(c2[k])).all(), k
            # the accumulation differs only in summation order
            err = float(jnp.abs(a1[k] - a2[k]).max())
            assert err < 1e-5, (k, err)
        # and both match dense W @ C(d) mixing
        W = jnp.asarray(plan.W, jnp.float32)
        for k in d:
            ref = jnp.einsum("mn,n...->m...", W, np.asarray(c1[k]))
            assert float(jnp.abs(ref - a1[k]).max()) < 1e-5, k
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.multidevice
def test_trainer_topo_schedule_composed_session():
    out = run_in_devices(8, """
        import numpy as np
        from repro.compat import make_mesh, set_mesh
        from repro.configs import get_smoke
        from repro.configs.base import AdaptConfig, RunConfig, ShapeConfig
        from repro.train import make_trainer
        from repro.data import SyntheticLMData
        from repro.comm import Compose

        mesh = make_mesh((4, 2), ("data", "model"))
        arch = get_smoke("qwen3-8b")
        shape = ShapeConfig("t", 64, 8, "train")
        ladder = ("dense", "int8:block=64", "ternary:block=64")
        run = RunConfig(
            consensus_axis="data", wire="int8:block=64", topology="ring",
            alpha=0.05, optimizer="sgd",
            adapt=AdaptConfig(enabled=True, interval=2, ladder=ladder,
                              bit_budget=2e6,
                              topo_schedule=((3, "complete"),)))
        tr = make_trainer(mesh, arch, run, shape)
        assert tr.n_nodes == 4
        policy = tr.comm_policy()
        assert isinstance(policy, Compose) and policy.topo is not None
        state = tr.init_state(0)
        data = SyntheticLMData(vocab_size=arch.vocab_size, seq_len=64,
                               global_batch=8, n_nodes=4)
        session = tr.comm_session(state, data.batch, policy=policy,
                                  track_history=False)
        with set_mesh(mesh):
            res = session.run(6)
        tm = policy.topo
        assert [s for s, old, new, _ in tm.switch_log] == [3], tm.switch_log
        assert tm.violations == 0, tm.violations
        # every step keyed (topo, rung); switching stayed within the bank
        assert all(k[0] == "topo" or k == "outage"
                   for k in res.plan_per_step), res.plan_per_step
        topos = {k[1] for k in res.plan_per_step if k[0] == "topo"}
        assert topos == {"ring", "complete"}, topos
        assert res.bank_stats["builds"] <= len(ladder) * 2 + 1, res.bank_stats
        assert res.bank_stats["builds"] == len(set(res.plan_per_step))
        print("OK", res.bank_stats, sorted(topos))
    """, timeout=560)
    assert "OK" in out
