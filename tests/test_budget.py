"""Bandwidth-budgeted scheduling (repro.adapt.budget): the budget is a
HARD constraint (flat-layout-costed bits <= budget at every step;
token-bucket mode: cumulative <= cumulative budget + initial burst), the
maximin objective is monotone in budget, outages are budget-0 windows
(runtime.fault adapters), switching lives in the PlanBank (LRU compile
count asserted via the compile-counter hook), and the benchmark harness
fails loudly on false deterministic artifact flags."""
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO, run_in_devices

from repro.adapt import (BudgetController, BudgetSchedule, PlanBank,
                         TokenBucket, budgeted_run, gaussian_probes,
                         ladder_from_specs, rung_key)
from repro.adapt.policies import BudgetPolicy
from repro.core import consensus as cons, problems
from repro.core.wire import flat_tree_wire_bits, make_wire
from repro.runtime.fault import (OUTAGE_SPEC, OutageBudgetSchedule,
                                 StragglerSim, outage_plan,
                                 outage_windows_from_sim)

LADDER = ("dense", "int8:block=64", "hybrid:block=128,top_j=4",
          "ternary:block=128")
SHAPES = ((3, 130), (257,), (2, 2, 128))


def make_controller(**kw):
    kw.setdefault("ladder", ladder_from_specs(LADDER, level="wire"))
    kw.setdefault("shapes", SHAPES)
    kw.setdefault("neighbors", 2)
    kw.setdefault("eta_min", 2.0)
    return BudgetController(**kw)


# ---------------------------------------------------------------------------
# schedule + bucket
# ---------------------------------------------------------------------------
class TestSchedule:
    def test_constant_ramp_duty(self):
        assert BudgetSchedule(bits=100.0).budget_at(7) == 100.0
        r = BudgetSchedule(bits=0.0, kind="ramp", bits_end=100.0,
                          ramp_steps=10)
        assert r.budget_at(0) == 0.0 and r.budget_at(5) == 50.0
        assert r.budget_at(10) == 100.0 == r.budget_at(99)
        d = BudgetSchedule(bits=80.0, kind="duty", period=4, duty=0.5,
                          off_bits=5.0)
        assert [d.budget_at(t) for t in range(5)] == [80, 80, 5, 5, 80]

    def test_parse(self):
        s = BudgetSchedule.parse("constant", 42.0)
        assert s.kind == "constant" and s.bits == 42.0
        s = BudgetSchedule.parse("ramp:end=10,steps=5", 2.0)
        assert s.kind == "ramp" and s.bits_end == 10.0 and s.ramp_steps == 5
        s = BudgetSchedule.parse("duty:period=8,duty=0.25", 64.0)
        assert s.budget_at(0) == 64.0 and s.budget_at(3) == 0.0
        with pytest.raises(ValueError):
            BudgetSchedule.parse("sawtooth", 1.0)

    def test_token_bucket_invariant(self):
        b = TokenBucket(capacity=100.0, balance=30.0)
        assert b.initial == 30.0
        b.fill(50.0)
        assert b.balance == 80.0
        assert b.spend(60.0) and b.balance == pytest.approx(20.0)
        assert not b.spend(21.0)            # overdraft refused
        b.fill(500.0)                       # clipped at capacity
        assert b.balance == 100.0
        assert b.spent <= b.filled + b.initial

    def test_outage_budget_schedule(self):
        sched = OutageBudgetSchedule(base=BudgetSchedule(bits=64.0),
                                     windows=((2, 4), (7, 8)))
        vals = [sched.budget_at(t) for t in range(9)]
        assert vals == [64, 64, 0, 0, 64, 64, 64, 0, 64]

    def test_outage_windows_from_sim(self):
        sim = StragglerSim(prob=0.9, seed=3)
        wins = outage_windows_from_sim(sim, n_steps=50, n_classes=2)
        flat = {t for a, b in wins for t in range(a, b)}
        for t in range(50):
            assert (t in flat) == (len(sim.dropped(t, 2)) == 2)


# ---------------------------------------------------------------------------
# the dual knapsack
# ---------------------------------------------------------------------------
class TestBudgetController:
    def test_budget_is_hard_and_maximin_monotone(self):
        bc = make_controller()
        probes = gaussian_probes(SHAPES, seed=1)
        cheapest = bc.vector_cost(
            [min(range(len(LADDER)), key=lambda r: bc._leaf_cost[l][r])
             for l in range(len(SHAPES))])
        budgets = [cheapest * f for f in (0.5, 1.0, 1.7, 3.0, 8.0, 50.0)]
        prev = -1.0
        for B in budgets:
            dec = bc.select_budgeted(probes, B)
            if dec.specs is None:
                assert B < cheapest           # only below the cheapest mix
                continue
            assert dec.bits <= B * (1 + 1e-6)
            # exact flat accounting: decision bits == the mixed layout cost
            fmts = [make_wire(s) for s in dec.specs]
            assert dec.bits == pytest.approx(
                flat_tree_wire_bits(fmts, list(SHAPES)) * bc.neighbors)
            assert dec.min_snr >= prev - 1e-9   # more budget, >= SNR
            prev = dec.min_snr

    def test_blackout_below_cheapest(self):
        bc = make_controller()
        dec = bc.select_budgeted(gaussian_probes(SHAPES, seed=0), 10.0)
        assert dec.specs is None and dec.reason == "blackout"
        assert dec.bits == 0.0

    def test_silence_floor(self):
        # a budget that only affords sub-floor SNR -> silence, bank bits
        bc = make_controller(min_useful_snr=1e3)
        probes = gaussian_probes(SHAPES, seed=1)
        cheap = bc.vector_cost([3] * len(SHAPES))
        dec = bc.select_budgeted(probes, cheap * 1.5)
        assert dec.specs is None and dec.reason == "silence"
        # enough budget for int8/dense clears the floor again
        dec = bc.select_budgeted(probes, 1e9)
        assert dec.specs is not None and dec.min_snr >= 1e3

    def test_snr_cap_saturates(self):
        bc = make_controller(snr_cap=5.0)
        dec = bc.select_budgeted(gaussian_probes(SHAPES, seed=1), 1e9)
        full = make_controller()
        ref = full.select_budgeted(gaussian_probes(SHAPES, seed=1), 1e9)
        assert dec.bits <= ref.bits          # stops buying at the cap
        assert dec.min_snr >= 5.0

    def test_no_false_blackout_from_lcm_padding(self):
        # leaf-local cheapest = [int8:64 for the scalar, ternary:512 for
        # the big leaf], but mixing them pads the scalar's row to the lcm
        # (512) making the JOINT cost exceed uniform ternary — the
        # controller must fall back to the cheapest uniform vector, not
        # declare a blackout while a feasible vector exists
        bc = BudgetController(
            ladder=ladder_from_specs(("int8:block=64", "ternary:block=512"),
                                     level="wire"),
            shapes=((1,), (4096,)), neighbors=1)
        uniform_ternary = bc.vector_cost([1, 1])
        mixed = bc.vector_cost([0, 1])
        assert uniform_ternary < mixed      # the coupling this guards
        probes = gaussian_probes(bc.shapes, seed=0)
        dec = bc.select_budgeted(probes, uniform_ternary * 1.05)
        assert dec.specs is not None, "false blackout"
        assert dec.bits <= uniform_ternary * 1.05 * (1 + 1e-9)

    def test_compressor_level_rungs_rejected(self):
        with pytest.raises(TypeError):
            make_controller(ladder=ladder_from_specs(
                ("ternary",), level="compressor"))


# ---------------------------------------------------------------------------
# the policy: per-step enforcement
# ---------------------------------------------------------------------------
class TestBudgetPolicy:
    def test_hard_cap_every_step_duty(self):
        bc = make_controller(neighbors=1)
        big = bc.vector_cost([0] * len(SHAPES)) * 2   # dense fits
        sched = BudgetSchedule(bits=big, kind="duty", period=4, duty=0.5,
                               off_bits=0.0)
        pol = BudgetPolicy(controller=bc, schedule=sched, cadence=3)
        pol.initial_spec()
        for step in range(1, 12):
            pol.decide(step, None)
        assert len(pol.spend_log) == 12
        for step, budget, _, bits, _ in pol.spend_log:
            assert bits <= budget * (1 + 1e-9), (step, bits, budget)
            if budget == 0.0:
                assert bits == 0.0           # off-phase = blackout
        specs = {s for s, _, _, b, _ in pol.spend_log if b == 0.0}
        assert specs                          # some blackout steps happened

    def test_token_bucket_cumulative_and_bursts(self):
        bc = make_controller(neighbors=1)
        dense_cost = bc.vector_cost([0] * len(SHAPES))
        fill = dense_cost * 0.6               # per-step budget < dense cost
        bucket = TokenBucket(capacity=dense_cost * 3)
        pol = BudgetPolicy(controller=bc, schedule=BudgetSchedule(bits=fill),
                           cadence=1, bucket=bucket)
        pol.initial_spec()
        cum_bits = cum_budget = 0.0
        burst = False
        for step in range(0, 20):
            if step:
                pol.decide(step, None)
            s, budget, _, bits, _ = pol.spend_log[-1]
            cum_bits += bits
            cum_budget += budget
            assert cum_bits <= cum_budget + bucket.initial + 1e-6
            burst |= bits > budget + 1e-6     # banked bits bought a burst
        assert burst
        assert bucket.spent == pytest.approx(cum_bits)

    def test_wall_clock_budget_stays_hard(self):
        """Deadline-aware link (BudgetSchedule.from_wall_clock): measured
        slow steps shrink the live budget and the per-step cap still binds
        on the SHRUNK value."""
        bc = make_controller(neighbors=1)
        dense = bc.vector_cost([0] * len(SHAPES))
        sched = BudgetSchedule.from_wall_clock(slo_ms=100.0,
                                               bits=dense * 1.05, decay=0.0)
        pol = BudgetPolicy(controller=bc, schedule=sched, cadence=1)
        pol.initial_spec()
        # on-SLO (no measurement yet): base budget, dense affordable
        assert pol.spend_log[-1][3] == pytest.approx(dense)
        sched.record_wall_time(400.0)         # 4x over SLO -> quarter budget
        pol.decide(1, None)
        _, budget, _, bits, _ = pol.spend_log[-1]
        assert budget == pytest.approx(dense * 1.05 / 4.0)
        assert 0 < bits <= budget * (1 + 1e-9)      # downgraded, still capped
        sched.record_wall_time(25.0)          # 4x under SLO -> clamped boost
        pol.decide(2, None)
        _, budget2, _, bits2, _ = pol.spend_log[-1]
        assert budget2 == pytest.approx(dense * 1.05 * sched.max_scale)
        assert bits2 == pytest.approx(dense)  # dense affordable again

    def test_outage_window_and_recovery(self):
        bc = make_controller(neighbors=1)
        base = bc.vector_cost([1] * len(SHAPES)) * 1.2
        sched = OutageBudgetSchedule(base=BudgetSchedule(bits=base),
                                     windows=((3, 6),))
        pol = BudgetPolicy(controller=bc, schedule=sched, cadence=100)
        out = [rung_key(pol.initial_spec())]
        for step in range(1, 9):
            out.append(rung_key(pol.decide(step, None)))
        for t in (3, 4, 5):
            assert out[t] == OUTAGE_SPEC, (t, out)
        # recovery is immediate (off-cadence stale-outage re-solve)
        assert out[6] != OUTAGE_SPEC
        assert out[2] != OUTAGE_SPEC


# ---------------------------------------------------------------------------
# end-to-end budgeted DC-DGD
# ---------------------------------------------------------------------------
def test_budgeted_run_respects_budget_and_converges():
    prob = problems.quadratic(n_nodes=5, dim=64, seed=3)
    W = cons.W1_PAPER
    eta = cons.spectrum(W).snr_threshold
    ladder = ["dense", "int8:block=64", "ternary:block=64"]
    int8_cost = 5 * make_wire("int8:block=64").wire_bits((64,))
    r = budgeted_run(prob, W, ladder, lambda t: 0.08 / jnp.sqrt(t), 80,
                     jax.random.PRNGKey(0),
                     schedule=BudgetSchedule(bits=0.7 * int8_cost),
                     token_bucket=True, bucket_cap_steps=4.0, cadence=1,
                     min_useful_snr=eta * 1.05)
    assert r["budget_violations"] == 0
    assert np.isfinite(r["f_bar"]).all()
    # burst-or-silence: both blackouts and transmissions happened
    kinds = set(r["spec_per_step"])
    assert OUTAGE_SPEC in kinds and len(kinds) >= 2, kinds
    # blackout steps cost zero, others cost the flat-layout bits
    for spec, bits in zip(r["spec_per_step"], r["bits"]):
        assert (bits == 0.0) == (spec == OUTAGE_SPEC)
    # cumulative spend bounded by cumulative budget + initial burst
    allowance = np.cumsum(r["budget_per_step"]) + 4.0 * 0.7 * int8_cost
    assert (r["cum_bits"] <= allowance * (1 + 1e-9)).all()


def test_outage_plan_zero_bits_and_identity_mix():
    from repro.core.gossip import GossipPlan, plan_wire_bits_per_step
    plan = GossipPlan(consensus_axes=("pod", "data"), dims=(2, 4), n_nodes=8,
                      mode="circulant",
                      offsets=(((0, 0), 0.5), ((0, 1), 0.25), ((0, 3), 0.25)),
                      W=np.eye(8), fmt=make_wire("ternary:block=64"))
    off = outage_plan(plan)
    assert off.n_out == 0 and off.offsets == (((0, 0), 1.0),)
    assert off.fmt.name == "dense" and off.leaf_fmts is None
    assert plan_wire_bits_per_step(off, [(3, 130), (257,)]) == 0
    assert np.allclose(off.W, np.eye(8))


# ---------------------------------------------------------------------------
# PlanBank LRU: exact compile counts via the compile-counter hook
# ---------------------------------------------------------------------------
class TestPlanBankCompileCount:
    @staticmethod
    def _bank(max_size):
        traces = []          # one append per jit TRACE (= per compilation)
        hook_keys = []

        def build(key):
            width = len(key) if isinstance(key, tuple) else 1

            @jax.jit
            def f(x):
                traces.append(key)
                return x * float(width)

            f(jnp.ones(4))   # compile eagerly so traces counts builds
            return f

        bank = PlanBank(build, max_size=max_size,
                        on_build=hook_keys.append)
        return bank, traces, hook_keys

    def test_cycling_within_capacity_never_recompiles(self):
        bank, traces, hook = self._bank(max_size=3)
        keys = [("a",), ("a", "b"), ("a", "b", "c")]
        for _ in range(4):
            for k in keys:
                bank.get(k)
        assert len(traces) == 3 == len(hook) == bank.builds
        assert bank.hits == 9 and bank.evictions == 0

    def test_cycling_beyond_capacity_exact_compiles(self):
        bank, traces, hook = self._bank(max_size=3)
        keys = [("a",), ("b",), ("c",), ("d",)]
        for _ in range(2):
            for k in keys:
                bank.get(k)
        # LRU of 3 cycling 4 keys: every get misses -> 8 builds, 5 evictions
        assert len(traces) == 8 == len(hook) == bank.builds
        assert bank.hits == 0 and bank.evictions == 5

    def test_rung_key_collapse_shares_plan(self):
        bank, traces, hook = self._bank(max_size=3)
        uniform = ("ternary:block=64",) * 5
        f1 = bank.get(rung_key(uniform))
        f2 = bank.get(rung_key("ternary:block=64"))
        assert f1 is f2 and bank.builds == 1 and len(traces) == 1
        mixed = ("ternary:block=64", "dense") + ("ternary:block=64",) * 3
        assert rung_key(mixed) != rung_key(uniform)
        bank.get(rung_key(mixed))
        assert bank.builds == 2


# ---------------------------------------------------------------------------
# benchmark harness: false deterministic flags fail loudly
# ---------------------------------------------------------------------------
class TestArtifactFlagGate:
    @staticmethod
    def _run_mod():
        sys.path.insert(0, str(REPO))
        try:
            from benchmarks import run as bench_run
        finally:
            sys.path.pop(0)
        return bench_run

    def test_false_flag_fails_loudly(self, tmp_path, capsys):
        bench_run = self._run_mod()
        (tmp_path / "BENCH_gossip.json").write_text(json.dumps(
            {"bit_exact": {"flat": True, "flat_pallas": False},
             "wire_bits_equal": True}))
        bad = bench_run.check_artifact_flags(tmp_path)
        assert bad == ["BENCH_gossip.json:bit_exact.flat_pallas=False"]
        assert bench_run.enforce_artifact_flags(0, tmp_path) == 1
        assert "ARTIFACT-REGRESSION" in capsys.readouterr().out

    def test_true_flags_pass(self, tmp_path):
        bench_run = self._run_mod()
        (tmp_path / "BENCH_gossip.json").write_text(json.dumps(
            {"bit_exact": {"flat": True}, "wire_bits_equal": True}))
        assert bench_run.check_artifact_flags(tmp_path) == []
        assert bench_run.enforce_artifact_flags(0, tmp_path) == 0
        # missing artifact: the suite that writes it already gated the rc
        assert bench_run.check_artifact_flags(tmp_path / "nope") == []


# ---------------------------------------------------------------------------
# multidevice: the 2D-torus trainer never exceeds the per-step budget,
# including across an outage window (satellite 3)
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_budgeted_trainer_torus_respects_budget():
    out = run_in_devices(8, """
        import jax, numpy as np
        from repro.compat import make_mesh, set_mesh
        from repro.configs import get_smoke
        from repro.configs.base import AdaptConfig, RunConfig, ShapeConfig
        from repro.train import make_trainer
        from repro.data import SyntheticLMData
        from repro.adapt import rung_key
        from repro.runtime.fault import OUTAGE_SPEC, OutageBudgetSchedule

        mesh = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        arch = get_smoke('qwen3-8b')
        shape = ShapeConfig('t', 64, 8, 'train')
        ladder = ('int8:block=64', 'ternary:block=64')
        run = RunConfig(consensus_axis='data', wire='int8:block=64',
                        topology='torus', alpha=0.05, optimizer='sgd',
                        adapt=AdaptConfig(enabled=True, bit_budget=1.0,
                                          ladder=ladder))
        tr = make_trainer(mesh, arch, run, shape)
        # consensus spans the 2x2 (pod, data) torus; model axis shards TP
        assert tr.n_nodes == 4 and tr.plan.mode == 'circulant'
        assert len(tr.plan.dims) == 2 and tr.plan.n_out >= 2

        n_leaves = len(tr.gossip_leaf_shapes())
        int8_bits = tr.wire_bits_for('int8:block=64')
        # budget = exactly the int8 plan, with an outage window at steps 3-4
        import dataclasses
        run = dataclasses.replace(
            run, adapt=dataclasses.replace(run.adapt,
                                           bit_budget=float(int8_bits)))
        tr.run = run
        policy = tr.budget_policy(cadence=1)
        policy.schedule = OutageBudgetSchedule(base=policy.schedule,
                                               windows=((3, 5),))
        bank = tr.wire_bank(max_size=4)
        active = rung_key(policy.initial_spec())
        step_fn = bank.get(active)
        state = tr.init_state(0)
        data = SyntheticLMData(vocab_size=arch.vocab_size, seq_len=64,
                               global_batch=8, n_nodes=4)
        cum_bits = cum_budget = 0.0
        with set_mesh(mesh):
            for i in range(7):
                state, m = step_fn(state, data.batch(i))
                budget = policy.schedule.budget_at(i)
                bits = tr.wire_bits_for(active)
                # the policy's accounted spend == the plan's actual bits
                srow = [r for r in policy.spend_log if r[0] == i][-1]
                assert srow[3] == bits, (i, srow, bits)
                # HARD per-step budget, every step
                assert bits <= budget * (1 + 1e-9), (i, bits, budget)
                if 3 <= i < 5:
                    assert active == OUTAGE_SPEC and bits == 0, (i, active)
                else:
                    assert bits > 0, (i, active)
                cum_bits += bits; cum_budget += budget
                assert cum_bits <= cum_budget * (1 + 1e-9)
                nxt = rung_key(policy.decide(i + 1, None))
                if nxt != active:
                    active = nxt
                    step_fn = bank.get(active)
        assert np.isfinite(float(m['loss']))
        assert bank.stats()['builds'] <= 3             # int8 / outage (+1)
        print('OK', bank.stats(), round(float(m['loss']), 3))
    """, timeout=560)
    assert "OK" in out
