"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp oracles in kernels/ref.py (element-exact), plus statistical
unbiasedness of the full encode->decode roundtrip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import hybrid as H, ref as R, ternary as T
from repro.kernels import ops


SHAPES = [(8, 512), (32, 512), (8, 1024), (64, 2048)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_ternary_encode_matches_ref(shape, dtype):
    Rr, B = shape
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 3).astype(dtype)
    bits = jax.random.bits(jax.random.PRNGKey(1), shape, jnp.uint32)
    c1, s1 = T.ternary_encode(x, bits, block=B, interpret=True)
    c2, s2 = R.ternary_encode_ref(x, bits)
    assert (np.asarray(c1) == np.asarray(c2)).all()
    np.testing.assert_allclose(s1, s2, rtol=1e-6)
    assert c1.dtype == jnp.uint8 and c1.shape == (Rr, B // 4)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("weight", [1.0, 0.25, -0.6])
def test_ternary_decode_axpy_matches_ref(shape, weight):
    Rr, B = shape
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 2
    bits = jax.random.bits(jax.random.PRNGKey(1), shape, jnp.uint32)
    codes, scales = R.ternary_encode_ref(x, bits)
    acc = jax.random.normal(jax.random.PRNGKey(2), shape)
    y1 = T.ternary_decode_axpy(codes, scales, acc, weight, block=B,
                               interpret=True)
    y2 = R.ternary_decode_axpy_ref(codes, scales, acc, weight)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("top_j", [2, 4, 8])
def test_hybrid_matches_ref(shape, top_j):
    Rr, B = shape
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3
    bits = jax.random.bits(jax.random.PRNGKey(1), shape, jnp.uint32)
    h1 = H.hybrid_encode(x, bits, block=B, top_j=top_j, interpret=True)
    h2 = R.hybrid_encode_ref(x, bits, top_j)
    for a, b in zip(h1, h2):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), rtol=1e-6)
    acc = jax.random.normal(jax.random.PRNGKey(2), shape)
    z1 = H.hybrid_decode_axpy(*h1, acc, 0.4, block=B, interpret=True)
    z2 = R.hybrid_decode_axpy_ref(*h2, acc, 0.4)
    np.testing.assert_allclose(z1, z2, rtol=1e-5, atol=1e-6)


def test_hybrid_outliers_are_exact():
    """top-j elements must decode EXACTLY (the §IV anchor property)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 512)) * 5
    bits = jax.random.bits(jax.random.PRNGKey(1), (8, 512), jnp.uint32)
    codes, scale, oval, oidx = H.hybrid_encode(x, bits, block=512, top_j=4,
                                               interpret=True)
    dec = R.hybrid_decode_axpy_ref(codes, scale, oval, oidx,
                                   jnp.zeros_like(x), 1.0)
    xm = np.abs(np.asarray(x))
    for r in range(8):
        top = np.argsort(-xm[r])[:4]
        np.testing.assert_allclose(np.asarray(dec)[r, top],
                                   np.asarray(x)[r, top], rtol=1e-6)


def test_roundtrip_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 512)) * 2
    outs = []
    for i in range(300):
        bits = jax.random.bits(jax.random.PRNGKey(i), x.shape, jnp.uint32)
        c, s = R.ternary_encode_ref(x, bits)
        outs.append(np.asarray(R.ternary_decode_axpy_ref(
            c, s, jnp.zeros_like(x), 1.0)))
    mean = np.stack(outs).mean(0)
    spread = np.stack(outs).std(0).max() / np.sqrt(300)
    assert np.abs(mean - np.asarray(x)).max() < 6 * spread + 1e-4


def test_ops_wrappers_padding():
    """ops.* adapt arbitrary (..., L) leaves to the kernel row layout."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 700))
    w = ops.ternary_encode(x, jax.random.PRNGKey(1), block=512)
    assert w["codes"].dtype == jnp.uint8
    h = ops.hybrid_encode(x, jax.random.PRNGKey(1), block=512, top_j=4)
    assert h["out_idx"].dtype == jnp.int32


@pytest.mark.parametrize("rows", [1, 3, 5, 7, 9, 13])
def test_kernels_pad_ragged_row_counts(rows):
    """Row counts that don't divide TILE_R must pad+strip, not assert —
    both encode AND the fused decode-axpy (the flat gossip path hands the
    kernels arbitrary rung-group row counts)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, 512)) * 2
    bits = jax.random.bits(jax.random.PRNGKey(1), x.shape, jnp.uint32)
    c1, s1 = T.ternary_encode(x, bits, block=512, interpret=True)
    c2, s2 = R.ternary_encode_ref(x, bits)
    assert c1.shape == (rows, 128)
    assert (np.asarray(c1) == np.asarray(c2)).all()
    acc = jax.random.normal(jax.random.PRNGKey(2), x.shape)
    y1 = T.ternary_decode_axpy(c2, s2, acc, 0.3, block=512, interpret=True)
    y2 = R.ternary_decode_axpy_ref(c2, s2, acc, 0.3)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    h1 = H.hybrid_encode(x, bits, block=512, top_j=2, interpret=True)
    h2 = R.hybrid_encode_ref(x, bits, 2)
    for a, b in zip(h1, h2):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float64),
                                      np.asarray(b, np.float64))
    z1 = H.hybrid_decode_axpy(*h1, acc, -0.25, block=512, interpret=True)
    z2 = R.hybrid_decode_axpy_ref(*h2, acc, -0.25)
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


def test_qi_layout_against_wire_pack2bit():
    """The kernels' quarter-interleaved packing and core.wire's sequential
    packing are bijective views of the same code vector: converting QI
    bytes through ref.qi_to_sequential must reproduce wire.pack2bit
    exactly, both packings must unpack to the same codes, and the decoded
    VALUES must agree element-for-element."""
    from repro.core import wire as W
    codes = jax.random.randint(jax.random.PRNGKey(0), (8, 1024), 0, 3)
    qi = R.pack2bit_qi(codes)
    seq = W.pack2bit(codes)
    np.testing.assert_array_equal(np.asarray(R.qi_to_sequential(qi)),
                                  np.asarray(seq))
    np.testing.assert_array_equal(np.asarray(R.sequential_to_qi(seq)),
                                  np.asarray(qi))
    np.testing.assert_array_equal(np.asarray(R.unpack2bit_qi(qi)),
                                  np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(W.unpack2bit(seq)),
                                  np.asarray(codes))
    np.testing.assert_array_equal(
        np.asarray(R.code_vals(R.unpack2bit_qi(qi))),
        np.asarray(W.code_to_val(W.unpack2bit(seq))))


def test_qi_roundtrip_through_encode():
    """End-to-end layout oracle: a Pallas-encoded plane re-packed to the
    sequential layout decodes identically through the jnp wire decoder."""
    from repro.core import wire as W
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 512)) * 2
    bits = jax.random.bits(jax.random.PRNGKey(4), x.shape, jnp.uint32)
    qi_codes, scales = T.ternary_encode(x, bits, block=512, interpret=True)
    dec_kernel = T.ternary_decode_axpy(qi_codes, scales,
                                       jnp.zeros_like(x), 1.0,
                                       block=512, interpret=True)
    seq_codes = R.qi_to_sequential(qi_codes)
    dec_wire = W.code_to_val(W.unpack2bit(seq_codes)) * scales
    np.testing.assert_array_equal(np.asarray(dec_kernel),
                                  np.asarray(dec_wire))
