"""repro.serve sync plane: per-rung reconstruction bit-exactness against
the per-leaf codec reference, bit-accounting parity with the budget
ledger, freshness-controller EMA/ladder behavior under budget starvation,
crash-consistent ServeSession kill/resume, and the donation-safe
``Server.update_params`` zero-recompile guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adapt import (BudgetController, BudgetPolicy, BudgetSchedule,
                         ladder_from_specs)
from repro.comm import BudgetComm, Compose, SessionCheckpointer, \
    restore_policy
from repro.core.wire import flat_tree_wire_bits, make_wire, per_leaf_flat_bits
from repro.serve import (SERVE_LADDER, FreshnessController, ScriptedFleet,
                         ServeSession, WeightDeltaWire, head_fanout)

LEAF_SHAPES = ((3, 70), (64,), (5, 64))
# the serve ladder plus TPU-width rungs (the Pallas-eligible tiles)
RUNGS = SERVE_LADDER + ("ternary:block=512", "hybrid:block=512,top_j=4")


def _leaves(key, scale=1.0):
    ks = jax.random.split(key, len(LEAF_SHAPES))
    return [scale * jax.random.normal(k, s, jnp.float32)
            for k, s in zip(ks, LEAF_SHAPES)]


# ---------------------------------------------------------------------------
# reconstruction-chain bit-exactness, per rung
# ---------------------------------------------------------------------------
class TestWeightDeltaWireRoundTrip:
    @pytest.mark.parametrize("rung", RUNGS)
    def test_chain_bit_identical_and_matches_leaf_reference(self, rung):
        """k sync ticks of a moving target: (a) the decoded differential
        equals the per-leaf WireFormat codec under the replayed
        ``split(key, n)[l]`` streams, (b) decode_axpy == decode + add
        bitwise, (c) trainer and replica chains stay bit-identical."""
        wire = WeightDeltaWire(LEAF_SHAPES)
        fmt = make_wire(rung)
        x = _leaves(jax.random.PRNGKey(0))
        xh_train = list(x)                   # replicas boot from x_0
        xh_rep = list(x)
        for t in range(4):
            x = [a + 0.1 * b for a, b in
                 zip(x, _leaves(jax.random.fold_in(jax.random.PRNGKey(9),
                                                   t)))]
            d = [a - b for a, b in zip(x, xh_train)]
            rng = jax.random.fold_in(jax.random.PRNGKey(5), t)
            payload = wire.encode(rung, d, rng)
            dhat = wire.decode(rung, payload)
            keys = jax.random.split(rng, len(d))
            for l, (dl, dh) in enumerate(zip(d, dhat)):
                ref = fmt.decode(fmt.encode(keys[l], dl), dl.shape,
                                 jnp.float32)
                np.testing.assert_array_equal(np.asarray(dh),
                                              np.asarray(ref),
                                              err_msg=f"leaf {l} tick {t}")
            via_axpy = wire.decode_axpy(rung, payload, xh_rep)
            xh_train = [a + b for a, b in zip(xh_train, dhat)]
            for l, (a, b) in enumerate(zip(xh_train, via_axpy)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=f"leaf {l} tick {t}")
            xh_rep = list(via_axpy)
        if rung == "dense":                  # lossless rung tracks exactly
            for a, b in zip(xh_train, x):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("rung", ["ternary:block=512",
                                      "hybrid:block=512,top_j=4"])
    def test_pallas_wire_matches_jnp_wire(self, rung):
        """use_pallas=True (interpret mode off-TPU) is bit-identical to
        the jnp row codecs — same payload decode, same axpy."""
        w_jnp = WeightDeltaWire(LEAF_SHAPES)
        w_pal = WeightDeltaWire(LEAF_SHAPES, use_pallas=True)
        d = _leaves(jax.random.PRNGKey(2))
        acc = _leaves(jax.random.PRNGKey(3))
        rng = jax.random.PRNGKey(4)
        pj = w_jnp.encode(rung, d, rng)
        pp = w_pal.encode(rung, d, rng)
        for a, b in zip(w_jnp.decode(rung, pj), w_pal.decode(rung, pp)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(w_jnp.decode_axpy(rung, pj, acc),
                        w_pal.decode_axpy(rung, pp, acc)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_broadcast_mode_replaces_chain(self):
        """differential=False (the fig10 strawman) codes x_t itself and
        REPLACES the reconstruction — dense broadcast lands exactly on
        x_t regardless of the previous chain state."""
        wire = WeightDeltaWire(LEAF_SHAPES)
        x = _leaves(jax.random.PRNGKey(6))
        xh = _leaves(jax.random.PRNGKey(7))  # arbitrary stale chain
        new_xh, applied, _, _ = wire.sync("dense", x, xh,
                                          jax.random.PRNGKey(8),
                                          differential=False)
        for a, b in zip(new_xh, x):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for ap, a, b in zip(applied, new_xh, xh):
            np.testing.assert_array_equal(np.asarray(ap),
                                          np.asarray(a) - np.asarray(b))


# ---------------------------------------------------------------------------
# bit accounting: wire table == budget ledger
# ---------------------------------------------------------------------------
class TestBitAccounting:
    @pytest.mark.parametrize("key", list(RUNGS) + [
        ("dense", "ternary:block=64", "int8:block=64")])
    def test_wire_bits_match_flat_tables(self, key):
        wire = WeightDeltaWire(LEAF_SHAPES)
        fmts = tuple(s.wire() for s in wire.specs_for(key))
        assert wire.wire_bits(key) == flat_tree_wire_bits(fmts, LEAF_SHAPES)
        assert wire.wire_bits(key) == sum(wire.per_leaf_bits(key))
        assert wire.per_leaf_bits(key) == per_leaf_flat_bits(fmts,
                                                             LEAF_SHAPES)

    def test_session_bits_equal_budget_ledger(self):
        """The session's per-tick ``wire_bits * head_fanout`` is the SAME
        number BudgetComm prices and logs (flat_tree_wire_bits *
        neighbors) — the ledger audits the actual link traffic."""
        wire = WeightDeltaWire(LEAF_SHAPES)
        fanout = head_fanout("star", 3)
        bc = BudgetComm(policy=BudgetPolicy(
            controller=BudgetController(
                ladder=ladder_from_specs(SERVE_LADDER, level="wire"),
                shapes=LEAF_SHAPES, neighbors=float(fanout), eta_min=0.0),
            schedule=BudgetSchedule(bits=float(
                wire.wire_bits("int8:block=64") * fanout)),
            cadence=1))
        policy = Compose(
            FreshnessController(ladder=SERVE_LADDER, staleness_target=2.0,
                                start_index=1, upgrade=0.0), bc)
        sess = ServeSession(
            wire=wire, policy=policy, fleet=ScriptedFleet(seed=1),
            state=ServeSession.init_state(_leaves(jax.random.PRNGKey(0)), 3),
            n_replicas=3, topology="star")
        res = sess.run(5)
        assert len(bc.spend_log) == 5
        for m, entry in zip(res.history, bc.spend_log):
            assert entry[0] == m["step"]
            assert entry[3] == m["bits"], (entry, m["step"])
        # nothing over budget, and nothing blacked out (the budget fits
        # the opening rung exactly)
        assert all(e[3] <= e[1] * (1 + 1e-9) for e in bc.spend_log)
        assert res.max_staleness == 0


# ---------------------------------------------------------------------------
# freshness controller
# ---------------------------------------------------------------------------
class TestFreshnessController:
    def test_ladder_walks_cheaper_then_richer(self):
        f = FreshnessController(ladder=SERVE_LADDER, staleness_target=2.0,
                                start_index=0)
        assert f.decide(0).key() == "dense"
        for s in (4.0, 4.0):
            f.note_staleness(s)
        assert f.decide(1).key() == "int8:block=64"       # EMA > target
        for s in (0.0,) * 6:                              # EMA decays home
            f.note_staleness(s)
        assert f.decide(2).key() == "dense"               # <= upgrade*target
        f2 = FreshnessController(ladder=SERVE_LADDER, staleness_target=2.0,
                                 start_index=1, upgrade=0.0)
        f2.decide(0)
        for s in (0.0,) * 4:
            f2.note_staleness(s)
        assert f2.decide(1).key() == "int8:block=64"      # no upgrades

    def test_ema_monotone_under_budget_starvation(self):
        """A budget below the cheapest rung blacks out every tick: the
        staleness samples strictly increase, so the EMA is monotone
        non-decreasing and the session's staleness grows without bound."""
        wire = WeightDeltaWire(LEAF_SHAPES)
        cheapest = min(wire.wire_bits(r) for r in SERVE_LADDER)
        fresh = FreshnessController(ladder=SERVE_LADDER,
                                    staleness_target=2.0)
        bc = BudgetComm(policy=BudgetPolicy(
            controller=BudgetController(
                ladder=ladder_from_specs(SERVE_LADDER, level="wire"),
                shapes=LEAF_SHAPES, neighbors=1.0, eta_min=0.0),
            schedule=BudgetSchedule(bits=0.5 * cheapest), cadence=1))
        emas = []
        sess = ServeSession(
            wire=wire, policy=Compose(fresh, bc),
            fleet=ScriptedFleet(seed=2),
            state=ServeSession.init_state(_leaves(jax.random.PRNGKey(1)), 1),
            n_replicas=1, fleet_steps_per_tick=2, log_every=1,
            on_log=lambda i, m, ran: emas.append(fresh.staleness_ema))
        res = sess.run(6)
        assert res.sync_bits == 0.0
        assert all(k == "outage" for k in res.plan_per_step)
        assert res.max_staleness == 6 * 2
        assert emas == sorted(emas) and emas[0] > 0.0
        assert all(e[3] == 0.0 for e in bc.spend_log)     # nothing spent


# ---------------------------------------------------------------------------
# crash-consistent kill/resume
# ---------------------------------------------------------------------------
class TestServeSessionResume:
    KILL_AT, TICKS = 6, 10

    def _harness(self, leaves, log_path):
        from repro.obs import JsonlSink, Recorder
        wire = WeightDeltaWire(LEAF_SHAPES)
        fresh = FreshnessController(ladder=SERVE_LADDER,
                                    staleness_target=2.0, start_index=1)
        bc = BudgetComm(policy=BudgetPolicy(
            controller=BudgetController(
                ladder=ladder_from_specs(SERVE_LADDER, level="wire"),
                shapes=LEAF_SHAPES, neighbors=2.0, eta_min=0.0),
            schedule=BudgetSchedule(
                bits=float(wire.wire_bits("int8:block=64") * 2)),
            cadence=1))
        policy = Compose(fresh, bc)
        rec = Recorder(JsonlSink(str(log_path)))
        sess = ServeSession(
            wire=wire, policy=policy, fleet=ScriptedFleet(seed=3),
            state=ServeSession.init_state(leaves, 2), n_replicas=2,
            topology="star", obs=rec)
        return sess, policy, fresh, bc, rec

    def test_kill_and_resume_bit_exact(self, tmp_path):
        from repro.ckpt import checkpoint as ck
        from repro.obs import diff_exact

        leaves = _leaves(jax.random.PRNGKey(0))
        sess, policy, fresh, bc, rec = self._harness(
            leaves, tmp_path / "base.jsonl")
        sess.checkpoint = SessionCheckpointer(
            directory=str(tmp_path / "ck"), policy=policy, every=2,
            retain=0)
        res = sess.run(self.TICKS)
        rec.close()
        assert len(bc.spend_log) == self.TICKS

        sess2, policy2, fresh2, bc2, rec2 = self._harness(
            leaves, tmp_path / "resume.jsonl")
        state2, manifest = ck.restore(tmp_path / "ck", self.KILL_AT,
                                      sess2.state)
        restore_policy(policy2, manifest["extra"]["policy"])
        sess2.state = state2
        assert len(bc2.spend_log) == self.KILL_AT     # ledger prefix back
        assert fresh2.index == fresh.index or True    # restored snapshot
        res2 = sess2.run(self.TICKS, start_step=self.KILL_AT)
        rec2.close()

        for a, b in zip(jax.tree.leaves(res.state),
                        jax.tree.leaves(res2.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert res2.plan_per_step == res.plan_per_step[self.KILL_AT:]
        assert bc2.spend_log == bc.spend_log
        assert fresh2.index == fresh.index
        assert fresh2.staleness_ema == fresh.staleness_ema
        assert fresh2.count == fresh.count
        exact = diff_exact(str(tmp_path / "base.jsonl"),
                           str(tmp_path / "resume.jsonl"),
                           from_step=self.KILL_AT)
        assert exact["ok"], exact["mismatches"]


# ---------------------------------------------------------------------------
# Server.update_params: donation-safe, zero recompiles
# ---------------------------------------------------------------------------
class TestServerUpdateParams:
    def test_update_params_single_compile_and_exact(self):
        from repro.compat import set_mesh
        from repro.configs import (ShapeConfig, default_run_config,
                                   get_smoke)
        from repro.launch.mesh import make_test_mesh
        from repro.models import init_model
        from repro.train.serve import make_server

        cfg = get_smoke("xlstm-350m")
        mesh = make_test_mesh((1, 1), ("data", "model"))
        shape = ShapeConfig(name="serve_decode", seq_len=32,
                            global_batch=2, kind="decode")
        server = make_server(mesh, cfg, default_run_config("xlstm-350m"),
                             shape)
        built = []
        server.add_update_build_hook(lambda key: built.append(key))
        params = jax.tree.map(
            lambda x: (x.astype(jnp.bfloat16)
                       if jnp.issubdtype(x.dtype, jnp.floating) else x),
            init_model(jax.random.PRNGKey(0), cfg))
        with set_mesh(mesh):
            p = params
            for t in range(4):
                delta = jax.tree.map(
                    lambda x: 0.01 * jnp.ones(x.shape, jnp.float32), p)
                expect = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), p, delta)
                p = server.update_params(p, delta)
                for a, b in zip(jax.tree.leaves(p),
                                jax.tree.leaves(expect)):
                    assert a.dtype == b.dtype
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
        # ONE build across 4 syncs: the delta apply path never re-runs
        # placement or recompiles (PlanBank on_build is the witness)
        assert len(built) == 1
        stats = server.update_stats()
        assert stats["builds"] == 1 and stats["hits"] == 3
