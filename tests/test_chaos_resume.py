"""Elastic-fleet resilience: the runtime.chaos schedule grammar, the
fault-plan drop-index range checks, Membership.join neighbor
initialization, live ElasticComm churn through one session, ChaosComm
slow-link budget scaling, and the crash-consistent session resume
(ledger + token-bucket continuity)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adapt import (BudgetController, BudgetPolicy, BudgetSchedule,
                         PlanBank, SNRFeedbackPolicy, TokenBucket,
                         ladder_from_specs)
from repro.comm import (BudgetComm, Compose, ElasticComm, PerLeafPlan,
                        RateComm, SessionCheckpointer, StaticComm,
                        TrainSession, restore_policy)
from repro.core.wire import make_wire
from repro.runtime.chaos import ChaosComm, FaultSchedule
from repro.runtime.elastic import Membership, apply_state_plan
from repro.topology import TopoSchedule, TopologyComm, topology

LADDER = ("dense", "int8:block=8", "ternary:block=8")
SHAPES = ((4, 8),)


# ---------------------------------------------------------------------------
# FaultSchedule grammar
# ---------------------------------------------------------------------------
class TestFaultSchedule:
    SCRIPT = ("crash:node=3,at=200 | rejoin:node=3,at=350 | "
              "slow:edge=1-2,span=100:180,factor=0.25 | outage:span=50:60")

    def test_parse_canonical_roundtrip(self):
        s = FaultSchedule.parse(self.SCRIPT)
        assert s.canonical() == self.SCRIPT
        assert FaultSchedule.parse(s.canonical()) == s
        # the cli-smoke form: space-free, same schedule
        assert FaultSchedule.parse(self.SCRIPT.replace(" ", "")) == s

    def test_churn_events_sorted_crash_first(self):
        s = FaultSchedule.parse("rejoin:node=9,at=5 | crash:node=1,at=5 | "
                                "crash:node=2,at=3")
        assert s.churn_events() == ((3, "crash", 2), (5, "crash", 1),
                                    (5, "rejoin", 9))

    def test_slow_scale_is_fleet_average(self):
        s = FaultSchedule.parse("slow:edge=0-1,span=2:4,factor=0.25")
        assert s.slow_scale(1, 4) == 1.0
        # (n_edges - k + sum 1/f) / n_edges = (4 - 1 + 4) / 4
        assert s.slow_scale(2, 4) == pytest.approx(7 / 4)
        assert s.slow_scale(4, 4) == 1.0          # [start, end) exclusive
        assert s.outage_windows() == ()

    @pytest.mark.parametrize("bad", [
        "wobble:at=3",                            # unknown clause kind
        "crash:node=1",                           # missing required arg
        "crash:node=1,at=2,extra=9",              # unknown arg
        "crash:nodeat",                           # malformed k=v
        "slow:edge=1-1,span=1:2,factor=0.5",      # self-edge
        "slow:edge=0-1,span=5:2,factor=0.5",      # empty span
        "slow:edge=0-1,span=1:2,factor=1.5",      # factor outside (0, 1]
        "outage:span=7",                          # span without ':'
    ])
    def test_malformed_raises(self, bad):
        with pytest.raises(ValueError):
            FaultSchedule.parse(bad)


# ---------------------------------------------------------------------------
# fault-plan drop indices are range-checked (stale-edge-space guard)
# ---------------------------------------------------------------------------
class TestFaultPlanRange:
    def _gossip_plan(self):
        from repro.core.gossip import GossipPlan
        from repro.core.wire import DenseWire
        t = topology("ring", n=8, lazy=0.25)
        _, offs = t.lowering((8,))
        return GossipPlan(consensus_axes=("data",), dims=(8,), n_nodes=8,
                          mode="circulant", offsets=offs, W=t.W,
                          fmt=DenseWire())

    def test_fault_plan_out_of_range_raises(self):
        from repro.runtime.fault import fault_plan, non_self_classes
        gp = self._gossip_plan()
        n = len(non_self_classes(gp))
        fault_plan(gp, [n - 1])                   # in range: fine
        with pytest.raises(IndexError, match="out of range"):
            fault_plan(gp, [n])
        with pytest.raises(IndexError, match="out of range"):
            fault_plan(gp, [-1])

    def test_drop_renormalize_dense_out_of_range_raises(self):
        from repro.runtime.fault import drop_renormalize_dense
        W = topology("ring", n=8, lazy=0.25).W
        drop_renormalize_dense(W, [0])            # in range: fine
        with pytest.raises(IndexError, match="out of range"):
            drop_renormalize_dense(W, [99])


# ---------------------------------------------------------------------------
# Membership.join warm-starts from an ACTUAL neighbor of the joiner
# ---------------------------------------------------------------------------
class TestMembershipJoin:
    @pytest.mark.parametrize("topo", ["ring", "erdos:p=0.3,seed=1",
                                      "expander:d=4"])
    def test_init_from_is_adjacent_in_rebuilt_graph(self, topo):
        m = Membership(node_ids=list(range(8)), topology=topo)
        plan = m.join(99)
        new_idx = m.n - 1
        adj = np.asarray(m.topo.adj)
        assert plan["init_from"] != new_idx
        assert adj[new_idx, plan["init_from"]]
        # and the state plan copies exactly that row (s reset to 0)
        x = jnp.arange(8 * 3, dtype=jnp.float32).reshape(8, 3)
        x2, s2 = apply_state_plan(x, jnp.ones((8, 3)), plan)
        np.testing.assert_array_equal(np.asarray(x2[-1]),
                                      np.asarray(x[plan["init_from"]]))
        assert np.abs(np.asarray(s2)).max() == 0


# ---------------------------------------------------------------------------
# ChaosComm: slow links lower to budget scaling, not drops
# ---------------------------------------------------------------------------
def _budget_comm(bits, neighbors=1.0, bucket=None):
    return BudgetComm(policy=BudgetPolicy(
        controller=BudgetController(
            ladder=ladder_from_specs(LADDER, level="wire"),
            shapes=SHAPES, neighbors=neighbors, eta_min=0.5),
        schedule=BudgetSchedule(bits=bits), cadence=1, bucket=bucket))


class TestChaosComm:
    def test_slow_span_scales_budget_and_retarget_preserves_scale(self):
        sched = FaultSchedule.parse("slow:edge=0-1,span=2:4,factor=0.5")
        bc = _budget_comm(bits=1e9, neighbors=2.0)
        ctl = bc.controller
        chaos = ChaosComm(schedule=sched, n_edges=4)
        members = (bc, chaos)
        chaos.pre_decide(0, members)
        assert ctl.neighbors == 2.0
        base = bc.plan_cost(PerLeafPlan.vector(("int8:block=8",)))
        # span opens: fleet-average scale (4 - 1 + 1/0.5)/4 = 1.25
        chaos.pre_decide(2, members)
        assert ctl.neighbors == pytest.approx(2.0 * 1.25)
        assert bc.plan_cost(PerLeafPlan.vector(("int8:block=8",))) \
            == pytest.approx(base * 1.25)
        # a topology retarget mid-span re-bases but keeps the live scale
        bc.retarget(0.9, neighbors=3.0)
        assert ctl.eta_min == 0.9
        assert ctl.neighbors == pytest.approx(3.0 * 1.25)
        # span closes: back to the (new) base exactly
        chaos.pre_decide(4, members)
        assert ctl.neighbors == pytest.approx(3.0)

    def test_fault_event_only_at_span_start(self):
        calls = []

        class Rec:
            def on_fault(self, step, **kw):
                calls.append((step, kw))

        sched = FaultSchedule.parse("slow:edge=0-1,span=2:4,factor=0.5")
        chaos = ChaosComm(schedule=sched, n_edges=4, recorder=Rec())
        for step in range(6):
            chaos.pre_decide(step, ())
        assert calls == [(2, {"cause": "slow", "edge": "0-1"})]
        # a MID-SPAN resume re-applies the scale but re-emits nothing:
        # the resumed event log must be an exact tail of the baseline's
        calls.clear()
        chaos2 = ChaosComm(schedule=sched, n_edges=4, recorder=Rec())
        chaos2.pre_decide(3, ())
        assert calls == [] and chaos2._applied_scale == sched.slow_scale(3, 4)


# ---------------------------------------------------------------------------
# live churn through ONE dcdgd session (ElasticComm)
# ---------------------------------------------------------------------------
class TestElasticChurn:
    def test_crash_rejoin_one_session_no_rebuilds(self):
        from repro.adapt.runner import _metric_step, make_dcdgd_session
        from repro.core import problems
        from repro.core.compressors import WireCompressor
        from repro.runtime.elastic import (rekey_dcdgd_state,
                                           restrict_problem)
        from repro.runtime.fault import peel_plan_key

        N, DIM = 6, 4
        prob = problems.quadratic(n_nodes=N, dim=DIM, seed=0)
        mem = Membership(list(range(N)), topology="ring")
        opening = mem.topo
        sched = TopoSchedule(entries=((0, "ring"),))
        topo_comm = TopologyComm(
            schedule=sched,
            topologies={sched.entries[0][1].canonical(): opening},
            dims=None,
            guaranteed_snr=lambda s: make_wire(s).snr_lower_bound(1))
        opening_c = topo_comm._active
        Ws, probs = {opening_c: np.asarray(opening.W)}, {opening_c: prob}

        def register_hook(key_, topo, node_ids):
            Ws[key_] = np.asarray(topo.W)
            probs[key_] = restrict_problem(prob, node_ids)

        def build_step(key_):
            topo_c, drops, inner = peel_plan_key(key_)
            W = jnp.asarray(Ws[topo_c or opening_c], jnp.float32)
            return _metric_step(probs[topo_c or opening_c], lambda t: 0.05,
                                W, WireCompressor(fmt=make_wire(inner)))

        session = make_dcdgd_session(prob, opening.W, lambda t: 0.05,
                                     jax.random.PRNGKey(0), None,
                                     bank_size=8, build_step=build_step)

        def state_hook(plan, topo, node_ids, key_):
            session.state = rekey_dcdgd_state(
                session.state, plan, probs[key_].grad, 0.05)

        elastic = ElasticComm(
            membership=mem, topo_comm=topo_comm,
            events=((2, "crash", 1), (4, "rejoin", 1)),
            state_hook=state_hook, register_hook=register_hook,
            shapes_fn=lambda n: ((n, DIM),))
        session.policy = Compose(StaticComm("dense"), elastic)

        shapes = []
        session.checkpoint = \
            lambda s, st, m: shapes.append(np.asarray(st.x).shape)
        res = session.run(6)

        assert [c[:3] for c in elastic.churn_log] == \
            [(2, "crash", 1), (4, "rejoin", 1)]
        assert (N - 1, DIM) in shapes             # the shrunken epoch ran
        assert np.asarray(res.state.x).shape == (N, DIM)
        # zero trainer rebuilds beyond the three epochs' plans
        distinct = set(res.plan_per_step)
        assert len(distinct) == 3
        assert res.bank_stats["builds"] == len(distinct)
        assert res.bank_stats["evictions"] == 0
        assert topo_comm.violations == 0


# ---------------------------------------------------------------------------
# crash-consistent resume: composed rate + budget(+bucket) + topology
# ---------------------------------------------------------------------------
def _toy_bank():
    """Deterministic toy steps whose dynamics DEPEND on the plan key, so a
    resume that replayed the wrong decision would diverge bitwise."""
    def build(key):
        inc = jnp.float32(0.125 * (1 + len(str(key)) % 7))

        def f(state):
            w = state["w"] + inc
            return {"w": w}, {
                "loss": w,
                "diff_power_leaves": jnp.full((1,), 100.0) + w,
                "noise_power_leaves": jnp.full((1,), 1.0)
                + 0.5 * jnp.cos(w)}
        return f
    return PlanBank(build, max_size=8)


def _composed_harness(bits):
    """A fresh rate + budget(token bucket) + topology session; called once
    per process stand-in (baseline / resumed)."""
    rate = RateComm(policy=SNRFeedbackPolicy(
        ladder=LADDER, eta_min=0.5, margin=1.0, upgrade=1.5, cadence=2),
        n_leaves=1, cadence=2)
    bc = _budget_comm(bits=bits, bucket=TokenBucket(capacity=3 * bits))
    tsched = TopoSchedule.parse("6:ring:lazy=0.0",
                                opening="complete:lazy=0.0")
    tc = TopologyComm(
        schedule=tsched,
        topologies={sp.canonical(): topology(sp, n=8)
                    for sp in tsched.specs()},
        dims=(8,),
        guaranteed_snr=lambda s: make_wire(s).snr_lower_bound(1))
    policy = Compose(rate, bc, tc)
    session = TrainSession(bank=_toy_bank(), policy=policy,
                           state={"w": jnp.float32(0.0)})
    return session, policy, rate, bc, tc


class TestSessionResume:
    def test_kill_and_resume_bit_exact_with_ledger_continuity(self, tmp_path):
        from repro.ckpt import checkpoint as ck

        dense_bits = _budget_comm(bits=1.0).plan_cost(
            PerLeafPlan.vector(("dense",)))
        bits = 0.6 * dense_bits                   # caps actually bind

        # baseline: 12 steps, checkpoint every 4, keep all checkpoints
        session, policy, rate, bc, tc = _composed_harness(bits)
        session.checkpoint = SessionCheckpointer(
            directory=str(tmp_path), policy=policy, every=4, retain=0)
        res = session.run(12)
        assert len(bc.spend_log) == 12 and tc.switch_log

        # kill at 8: a FRESH harness restores checkpoint + policy snapshot
        session2, policy2, rate2, bc2, tc2 = _composed_harness(bits)
        state2, manifest = ck.restore(tmp_path, 8, session2.state)
        restore_policy(policy2, manifest["extra"]["policy"])
        session2.state = state2
        assert len(bc2.spend_log) == 8            # ledger prefix restored
        res2 = session2.run(12, start_step=8)

        # bit-exact state, identical plan tail, continuous audit trails
        np.testing.assert_array_equal(np.asarray(res.state["w"]),
                                      np.asarray(res2.state["w"]))
        assert res2.plan_per_step == res.plan_per_step[8:]
        assert bc2.spend_log == bc.spend_log      # incl. the replayed tail
        for f in ("balance", "filled", "spent", "initial"):
            assert getattr(bc2.policy.bucket, f) \
                == getattr(bc.policy.bucket, f), f
        assert tc2.switch_log == tc.switch_log
        assert rate2.policy.index == rate.policy.index
        for a, b in zip(jax.tree.leaves(rate._tel),
                        jax.tree.leaves(rate2._tel)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDelayedSessionResume:
    """Kill/resume with an IN-FLIGHT delayed differential: the DelayComm
    snapshot (repro.comm.resume kind "delay") must restore the carried
    buffer so the resumed run bit-matches the uninterrupted one — state,
    plan tail, AND the obs step-event tail (obs.diff_exact from the kill
    step) — including under a chaos-schedule composition whose slow span
    straddles the resume point."""

    KILL_AT, STEPS = 12, 24
    # slow span opens after the kill: the resumed session must recompute
    # the budget scale from (schedule, step) alone, mid-flight carry intact
    CHAOS = "slow:edge=0-1,span=14:18,factor=0.5"

    @pytest.mark.parametrize("chaos", [None, CHAOS],
                             ids=["plain", "chaos-composed"])
    def test_kill_and_resume_bit_exact_with_inflight_carry(
            self, tmp_path, chaos):
        from test_async_gossip import build_delayed_fleet
        from repro.ckpt import checkpoint as ck
        from repro.obs import diff_exact

        base_log = tmp_path / "base.jsonl"
        resume_log = tmp_path / "resume.jsonl"
        ckpt_dir = tmp_path / "ckpt"

        base = build_delayed_fleet(str(base_log), steps=self.STEPS,
                                   ckpt_dir=ckpt_dir, chaos_schedule=chaos)
        res = base["session"].run(self.STEPS)
        base["recorder"].close()
        assert base["holder"].carry is not None   # buffer was in flight

        resumed = build_delayed_fleet(str(resume_log), steps=self.STEPS,
                                      ckpt_dir=None, chaos_schedule=chaos)
        state2, manifest = ck.restore(ckpt_dir, self.KILL_AT,
                                      resumed["session"].state)
        restore_policy(resumed["policy"], manifest["extra"]["policy"])
        resumed["session"].state = state2
        # the in-flight delayed differential came back with the policy
        assert resumed["holder"].carry is not None
        res2 = resumed["session"].run(self.STEPS, start_step=self.KILL_AT)
        resumed["recorder"].close()

        for a, b in zip(jax.tree.leaves(res.state),
                        jax.tree.leaves(res2.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert res2.plan_per_step == res.plan_per_step[self.KILL_AT:]
        exact = diff_exact(str(base_log), str(resume_log),
                           from_step=self.KILL_AT)
        assert exact["ok"], exact["mismatches"]
        assert exact["n_steps"] == self.STEPS - self.KILL_AT
