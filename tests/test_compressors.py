"""Property tests for SNR-constrained compressors (paper Definition 1) and
the fixed-shape wire formats — unbiasedness and SNR bounds via hypothesis.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.compressors import (BlockedHybrid, BlockedTernary, HybridChain,
                                    Identity, LowPrecision, Sparsifier,
                                    Ternary, make_compressor)
from repro.core.wire import (DenseWire, HybridWire, Int8Wire, RandKWire,
                             TernaryWire, TopKWire, make_wire)

N_MC = 400  # Monte-Carlo samples for moment checks


def mc_moments(fn, x, n=N_MC):
    outs = np.stack([np.asarray(fn(jax.random.PRNGKey(i), x))
                     for i in range(n)])
    return outs.mean(0), outs.var(0).sum()


vec = st.integers(3, 80).flatmap(
    lambda d: st.lists(st.floats(-10, 10, allow_nan=False, width=32),
                       min_size=d, max_size=d))


@settings(max_examples=12, deadline=None)
@given(vec, st.sampled_from([0.3, 0.5, 0.8]))
def test_sparsifier_unbiased_and_snr(v, p):
    """Ex. 1: E[C(z)] = z and E||eps||^2 <= (1-p)/p ||z||^2."""
    z = jnp.asarray(v, jnp.float32)
    comp = Sparsifier(p=p)
    mean, var = mc_moments(lambda k, x: comp(k, x), z)
    nz = float(jnp.sum(z**2))
    tol = 6 * np.sqrt(var / N_MC + 1e-12)
    assert np.abs(mean - np.asarray(z)).sum() <= tol + 1e-4
    # exact noise power: (1/p - 1) ||z||^2
    expect_var = (1 / p - 1) * nz
    assert var <= expect_var * 1.35 + 1e-3
    assert comp.snr_lower_bound(len(v)) == pytest.approx(p / (1 - p))


@settings(max_examples=12, deadline=None)
@given(vec)
def test_ternary_unbiased_and_noise_power(v):
    """Ex. 2: unbiased; noise power == sum |z_i|(||z||_inf - |z_i|)."""
    z = jnp.asarray(v, jnp.float32)
    comp = Ternary()
    mean, var = mc_moments(lambda k, x: comp(k, x), z)
    scale = float(jnp.max(jnp.abs(z)))
    expect = float(jnp.sum(jnp.abs(z) * (scale - jnp.abs(z))))
    tol = 6 * np.sqrt(var / N_MC + 1e-9) + 1e-4
    assert np.abs(mean - np.asarray(z)).sum() <= tol * len(v)
    assert var <= expect * 1.4 + 1e-3
    assert var >= expect * 0.6 - 1e-3


@settings(max_examples=8, deadline=None)
@given(vec, st.sampled_from([0.5, 1.0, 2.0]))
def test_hybrid_chain_snr_guarantee(v, eta):
    """§IV: the hybrid compressor's noise power respects ||z||^2 / eta."""
    z = jnp.asarray(v, jnp.float32)
    comp = HybridChain(eta=eta)
    mean, var = mc_moments(lambda k, x: comp(k, x), z, n=300)
    nz = float(jnp.sum(z**2))
    assert var <= nz / eta * 1.45 + 1e-3          # MC slack
    tol = 6 * np.sqrt(var / 300 + 1e-9) + 1e-4
    assert np.abs(mean - np.asarray(z)).sum() <= tol * len(v)
    assert comp.snr_lower_bound(len(v)) == eta


def test_blocked_ternary_noise_never_worse_than_global():
    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (2048,)) * jnp.exp(
        jax.random.normal(jax.random.PRNGKey(1), (2048,)))
    glob = Ternary()
    blk = BlockedTernary(block=256)
    _, var_g = mc_moments(lambda k, x: glob(k, x), z, n=150)
    _, var_b = mc_moments(lambda k, x: blk(k, x), z, n=150)
    assert var_b <= var_g * 1.05


def test_registry_roundtrip():
    for spec in ["identity", "sparsifier:p=0.8", "ternary",
                 "blocked_ternary:block=256", "lowprec:bits=8",
                 "hybrid:eta=2.0", "blocked_hybrid:block=256,top_j=2"]:
        c = make_compressor(spec)
        z = jnp.arange(1, 100, dtype=jnp.float32)
        out = c(jax.random.PRNGKey(0), z)
        assert out.shape == z.shape


# ---------------------------------------------------------------------------
# wire formats
# ---------------------------------------------------------------------------
WIRES = ["dense", "int8:block=64", "ternary:block=64",
         "hybrid:block=64,top_j=4", "randk:block=64,k=16"]


@pytest.mark.parametrize("spec", WIRES + ["topk:block=64,k=16"])
@pytest.mark.parametrize("shape", [(130,), (3, 64), (2, 5, 70)])
def test_wire_shape_roundtrip(spec, shape):
    fmt = make_wire(spec)
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    w = fmt.encode(jax.random.PRNGKey(1), x)
    y = fmt.decode(w, x.shape, x.dtype)
    assert y.shape == x.shape and y.dtype == x.dtype
    assert np.isfinite(np.asarray(y)).all()
    assert fmt.wire_bits(shape) > 0


@pytest.mark.parametrize("spec", WIRES)
def test_wire_unbiased(spec):
    fmt = make_wire(spec)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 3
    if spec == "dense":  # deterministic: exact, not just unbiased
        y = fmt.decode(fmt.encode(jax.random.PRNGKey(0), x), x.shape, x.dtype)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)
        return
    outs = np.stack([np.asarray(fmt.decode(fmt.encode(jax.random.PRNGKey(i), x),
                                           x.shape, x.dtype))
                     for i in range(N_MC)])
    err = np.abs(outs.mean(0) - np.asarray(x)).max()
    spread = outs.std(0).max() / np.sqrt(N_MC)
    assert err <= 6 * spread + 1e-5, f"{spec}: bias {err} vs {spread}"


def test_wire_bits_reflect_compression():
    shape = (4, 4096)
    dense = make_wire("dense").wire_bits(shape)
    tern = make_wire("ternary:block=512").wire_bits(shape)
    hyb = make_wire("hybrid:block=512,top_j=4").wire_bits(shape)
    int8 = make_wire("int8:block=512").wire_bits(shape)
    assert tern < dense / 12            # ~2.06 bits vs 32
    assert tern < hyb < int8 < dense    # §IV cost ordering
