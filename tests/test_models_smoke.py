"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs;
plus decode-vs-teacher-forcing consistency and cache machinery checks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke
from repro.models import (alloc_cache, decode_step, init_model, loss_fn,
                          model_axes, prefill)


def make_batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.encdec:
        batch["enc_embeds"] = jax.random.normal(
            key, (b, min(cfg.frontend_len, s), cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_loss_no_nan(name):
    cfg = get_smoke(name)
    params = init_model(jax.random.PRNGKey(0), cfg)
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(
        params, make_batch(cfg))
    assert np.isfinite(float(loss)), name
    assert float(loss) > 0
    assert np.isfinite(float(metrics["nll"]))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_decreases_loss(name):
    """A few SGD steps on a repeated batch must reduce the loss."""
    cfg = get_smoke(name)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, b=2, s=32)

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(
            lambda pp: loss_fn(pp, cfg, batch), has_aux=True)(p)
        return l, jax.tree.map(lambda w, gg: w - 0.5 * gg, p, g)

    l0, params = step(params)
    for _ in range(4):
        l1, params = step(params)
    assert float(l1) < float(l0), (name, float(l0), float(l1))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_model_axes_structure_matches(name):
    cfg = get_smoke(name)
    params = init_model(jax.random.PRNGKey(0), cfg)
    axes = model_axes(cfg)
    is_axes_leaf = lambda t: t is None or (isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t))
    pl = jax.tree.leaves(params)
    # None axes entries (weight-shared scan positions) carry no leaves
    al = [a for a in jax.tree.leaves(axes, is_leaf=is_axes_leaf)
          if a is not None]
    assert len(pl) == len(al), (name, len(pl), len(al))
    flat_p, _ = jax.tree_util.tree_flatten(params)
    for leaf, names in zip(pl, al):
        if names is not None:
            assert leaf.ndim == len(names), (name, leaf.shape, names)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_teacher_forcing(name):
    """prefill(t[:k]) + decode steps == logits of full forward — validates
    every cache type (KV / MLA-compressed / SSM / mLSTM / sLSTM / cross).
    MoE archs run with a generous capacity factor: capacity-based routing
    legitimately drops different tokens in a 16-token prefill batch than in
    single-token decode (measured corr 0.85 at cf=1.5 vs 1.0000 at cf=8)."""
    cfg = get_smoke(name)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_model(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    batch = make_batch(cfg, b=b, s=s, seed=3)
    toks = batch["tokens"]

    # teacher-forced logits at the last position
    full = dict(batch)
    cache_full = alloc_cache(cfg, b, s)
    logits_full, _ = jax.jit(lambda p, bt, c: prefill(p, cfg, bt, c))(
        params, full, cache_full)

    # prefill s-2, then decode the last two tokens
    pre = {k: (v[:, : s - 2] if v.ndim > 1 and k != "enc_embeds" else v)
           for k, v in batch.items()}
    cache = alloc_cache(cfg, b, s)
    logits, cache = jax.jit(lambda p, bt, c: prefill(p, cfg, bt, c))(
        params, pre, cache)
    dstep = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
    logits, cache = dstep(params, toks[:, s - 2], cache, jnp.int32(s - 2))
    logits, cache = dstep(params, toks[:, s - 1], cache, jnp.int32(s - 1))

    a = np.asarray(logits_full[:, : cfg.vocab_size], np.float32)
    bl = np.asarray(logits[:, : cfg.vocab_size], np.float32)
    # bf16 compute: compare top-1 agreement and correlation
    corr = np.corrcoef(a.ravel(), bl.ravel())[0, 1]
    assert corr > 0.99, (name, corr)


def test_sliding_window_masks_far_context():
    """SWA: token attends only within the window."""
    cfg = dataclasses.replace(get_smoke("h2o-danube-3-4b"), window=8,
                              n_layers=1)
    params = init_model(jax.random.PRNGKey(0), cfg)
    b, s = 1, 32
    t1 = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    t2 = t1.at[:, :8].set((t1[:, :8] + 7) % cfg.vocab_size)  # change far past
    c1 = alloc_cache(cfg, b, s)
    c2 = alloc_cache(cfg, b, s)
    l1, _ = prefill(params, cfg, {"tokens": t1}, c1)
    l2, _ = prefill(params, cfg, {"tokens": t2}, c2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-3)


def test_rolling_window_cache_decode():
    """window-bounded rolling cache == full cache for SWA decode."""
    cfg = dataclasses.replace(get_smoke("h2o-danube-3-4b"), window=8)
    params = init_model(jax.random.PRNGKey(0), cfg)
    b, s, extra = 1, 12, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + extra), 0,
                              cfg.vocab_size)
    pre = {"tokens": toks[:, :s]}

    full = alloc_cache(cfg, b, s + extra)
    lf, full = prefill(params, cfg, pre, full)
    # fill the rolling cache by decoding the prompt token by token
    roll = alloc_cache(cfg, b, s + extra, window_bounded=True)
    lr = None
    for i in range(s):
        lr, roll = decode_step(params, cfg, toks[:, i], roll, jnp.int32(i))
    for i in range(extra):
        lf, full = decode_step(params, cfg, toks[:, s + i], full,
                               jnp.int32(s + i))
        lr, roll = decode_step(params, cfg, toks[:, s + i], roll,
                               jnp.int32(s + i))
    corr = np.corrcoef(np.asarray(lf).ravel(), np.asarray(lr).ravel())[0, 1]
    assert corr > 0.999, corr


def test_moe_routing_balance_metrics():
    cfg = get_smoke("deepseek-v2-lite-16b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    _, metrics = loss_fn(params, cfg, make_batch(cfg))
    assert float(metrics["moe_lb_loss"]) > 0
    assert 0 <= float(metrics["moe_drop_frac"]) < 0.5


def test_zamba2_shared_attention_is_shared():
    """The shared block's params exist ONCE (true weight sharing)."""
    cfg = get_smoke("zamba2-7b")
    params = init_model(jax.random.PRNGKey(0), cfg)
    assert "shared" in params
    assert params["units"][2] is None  # shared position has no stacked params
