"""The trip-count-weighted HLO analyzer vs XLA ground truth on unrolled
programs (where XLA's own cost analysis is exact)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_stats import analyze, xla_cost_analysis


def _compiled_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_weighted_by_trip_count():
    w = jnp.ones((128, 128))
    x = jnp.ones((128, 128))

    def scan10(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=10)
        return y

    def unroll10(x):
        for _ in range(10):
            x = x @ w
        return x

    fs = analyze(_compiled_text(scan10, x))["flops"]
    fu = analyze(_compiled_text(unroll10, x))["flops"]
    expect = 10 * 2 * 128**3
    assert fs == pytest.approx(expect, rel=0.01)
    assert fu == pytest.approx(expect, rel=0.01)
    # XLA's aggregate undercounts the scan 10x — the reason analyze() exists
    c = jax.jit(scan10).lower(x).compile()
    assert xla_cost_analysis(c)["flops"] == pytest.approx(expect / 10, rel=0.01)


def test_nested_scan_weights_multiply():
    w = jnp.ones((64, 64))
    x = jnp.ones((64, 64))

    def g(x):
        def outer(c, _):
            y, _ = jax.lax.scan(lambda cc, __: (cc @ w, None), c, None,
                                length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    st = analyze(_compiled_text(g, x))
    assert st["flops"] == pytest.approx(20 * 2 * 64**3, rel=0.01)
    assert st["unknown_trip_counts"] == 0


def test_matches_xla_on_straightline_matmuls():
    a = jnp.ones((256, 512))
    b = jnp.ones((512, 128))

    def f(a, b):
        return jax.nn.relu(a @ b)

    txt = _compiled_text(f, a, b)
    st = analyze(txt)
    c = jax.jit(f).lower(a, b).compile()
    assert st["flops"] == pytest.approx(xla_cost_analysis(c)["flops"], rel=0.05)


def test_grad_flops_about_triple_forward():
    w = jnp.ones((128, 128))
    x = jnp.ones((8, 128))

    def loss(w):
        h = jnp.tanh(x @ w)
        return jnp.sum(h @ w)

    ff = analyze(_compiled_text(loss, w))["flops"]
    fg = analyze(_compiled_text(jax.grad(loss), w))["flops"]
    assert 2.0 <= fg / ff <= 4.5  # bwd ~ 2x fwd (+fwd recompute variance)


@pytest.mark.multidevice
def test_collectives_counted(devices8):
    out = devices8("""
        import jax, jax.numpy as jnp
        from repro.compat import make_mesh, set_mesh
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_stats import analyze
        mesh = make_mesh((8,), ("d",))
        x = jax.device_put(jnp.ones((8, 64)), NamedSharding(mesh, P("d")))

        def f(x):
            return jax.lax.with_sharding_constraint(
                jnp.broadcast_to(x.sum(0), (8, 64)), P("d"))

        with set_mesh(mesh):
            txt = jax.jit(f).lower(x).compile().as_text()
        st = analyze(txt)
        assert st["collectives"]["total"] > 0, st["collectives"]
        print("OK", st["collectives"]["counts"])
    """)
    assert "OK" in out
