"""Consensus-matrix machinery + the paper's greedy Algorithm 2."""
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import consensus as cons
from repro.core.hybrid_greedy import (brute_force_plan, greedy_plan,
                                      plan_noise_power)


class TestConsensusMatrices:
    @pytest.mark.parametrize("maker", [
        lambda: cons.metropolis_weights(cons.ring_adjacency(8)),
        lambda: cons.metropolis_weights(cons.torus_adjacency(4, 4)),
        lambda: cons.metropolis_weights(cons.complete_adjacency(6)),
        lambda: cons.metropolis_weights(cons.erdos_adjacency(10, 0.4)),
        lambda: cons.metropolis_weights(cons.star_adjacency(7), lazy=0.2),
        lambda: cons.W1_PAPER, lambda: cons.W2_PAPER,
        lambda: cons.fig3_topology_a(), lambda: cons.fig3_topology_b(),
    ])
    def test_valid(self, maker):
        W = maker()
        cons.validate_consensus_matrix(W)

    def test_lazy_lifts_lambda_n(self):
        adj = cons.ring_adjacency(8)
        s0 = cons.spectrum(cons.metropolis_weights(adj))
        s1 = cons.spectrum(cons.metropolis_weights(adj, lazy=0.3))
        assert s1.lambda_n > s0.lambda_n
        assert s1.snr_threshold < s0.snr_threshold

    def test_circulant_offsets(self):
        W = cons.ring_consensus(6)
        offs = cons.circulant_offsets(W)
        assert sorted(o for o, _ in offs) == [0, 1, 5]
        with pytest.raises(ValueError):
            cons.circulant_offsets(cons.fig3_topology_a())

    @given(st.integers(4, 12))
    @settings(max_examples=8, deadline=None)
    def test_metropolis_doubly_stochastic_any_graph(self, n):
        adj = cons.erdos_adjacency(n, 0.5, seed=n)
        W = cons.metropolis_weights(adj)
        cons.validate_consensus_matrix(W, adj)


class TestGreedyAlg2:
    @given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=4, max_size=10),
           st.sampled_from([0.5, 1.0, 2.0]))
    @settings(max_examples=25, deadline=None)
    def test_greedy_close_to_bruteforce(self, v, eta):
        z = np.asarray(v, np.float64)
        g = greedy_plan(z, eta)
        b = brute_force_plan(z, eta)
        # greedy is a heuristic; paper claims efficiency, we check it is
        # never worse than 1.3x optimal on tiny instances and always valid
        assert g.bits <= b.bits * 1.3 + 64
        # every ternary member satisfies condition (12) w.r.t. its anchor
        m = np.sort(np.abs(z))[::-1]
        for a, members in g.groups:
            for i in members:
                if i == a:
                    continue
                assert m[i] * (m[a] - m[i]) < m[i] ** 2 / eta + 1e-9

    @given(st.lists(st.floats(-50, 50, allow_nan=False, width=32),
                    min_size=5, max_size=30),
           st.sampled_from([0.5, 1.0, 2.0]))
    @settings(max_examples=25, deadline=None)
    def test_plan_respects_snr(self, v, eta):
        """Effective noise power of the plan <= ||z||^2 / eta (the §IV
        guarantee the ternary operator alone cannot give)."""
        z = np.asarray(v, np.float64)
        if np.sum(z * z) < 1e-12:
            return
        plan = greedy_plan(z, eta)
        noise = plan_noise_power(z, plan)
        assert noise <= np.sum(z * z) / eta + 1e-9

    def test_greedy_beats_pure_sparsifier_cost(self):
        rng = np.random.default_rng(0)
        z = rng.standard_normal(64)
        eta = 1.0
        plan = greedy_plan(z, eta)
        p = eta / (1 + eta)
        sparsifier_bits = (32 * p + 1 * (1 - p)) * 64
        assert plan.bits < sparsifier_bits
