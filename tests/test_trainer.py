"""Single-device trainer invariants: config resolution, SNR gate, state
structures, checkpoint-through-trainer roundtrip, data determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import set_mesh
from repro.configs import (PER_ARCH_RUN, SHAPES, cell_applicable,
                           default_run_config, get_arch, get_smoke,
                           input_specs)
from repro.configs.base import RunConfig, ShapeConfig
from repro.data import SyntheticLMData
from repro.launch.mesh import make_test_mesh
from repro.train import make_trainer


@pytest.fixture(scope="module")
def mesh1():
    return make_test_mesh((1, 1), ("data", "model"))


def test_consensus_axis_resolution(mesh1):
    arch = get_smoke("qwen3-8b")
    shape = ShapeConfig("t", 32, 4, "train")
    tr = make_trainer(mesh1, arch, RunConfig(consensus_axis="data"), shape)
    assert tr.consensus_axes == ("data",) and tr.n_nodes == 1
    tr2 = make_trainer(mesh1, arch, RunConfig(consensus_axis=None), shape)
    assert not tr2.node_mode
    # pod consensus without a pod axis degrades to 0 nodes -> allreduce-like
    tr3 = make_trainer(mesh1, arch, RunConfig(consensus_axis="pod"), shape)
    assert tr3.n_nodes == 1 and not tr3.node_mode


@pytest.mark.multidevice
def test_snr_gate_raises_on_bad_randk(devices8):
    out = devices8("""
        import jax
        from repro.compat import make_mesh, set_mesh
        from repro.configs import get_smoke
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.train import make_trainer
        mesh = make_mesh((8, 1), ("data", "model"))
        arch = get_smoke("qwen3-8b")
        shape = ShapeConfig("t", 32, 8, "train")
        # randk with k << block has a tiny guaranteed SNR -> must be gated
        try:
            make_trainer(mesh, arch,
                         RunConfig(consensus_axis="data", topology="ring",
                                   lazy_mixing=0.0, wire="randk:block=512,k=8"),
                         shape)
            raise SystemExit("gate did not fire")
        except ValueError as e:
            assert "Theorem-1" in str(e)
        # unsafe overrides
        tr = make_trainer(mesh, arch,
                          RunConfig(consensus_axis="data", topology="ring",
                                    lazy_mixing=0.0,
                                    wire="randk:block=512,k=8", unsafe=True),
                          shape)
        assert tr.snr_check[0] is False
        print("OK")
    """)
    assert "OK" in out


def test_single_node_uses_exact_wire(mesh1):
    arch = get_smoke("qwen3-8b")
    tr = make_trainer(mesh1, arch, RunConfig(consensus_axis="data",
                                             wire="ternary:block=512"),
                      ShapeConfig("t", 32, 4, "train"))
    # n_nodes == 1 degenerates to the exact allreduce path: no gossip plan,
    # no consensus state
    assert tr.plan is None and not tr.node_mode
    assert tr.snr_check[0] is True and "exact" in tr.snr_check[1]


def test_state_struct_matches_init(mesh1):
    arch = get_smoke("xlstm-350m")
    tr = make_trainer(mesh1, arch,
                      RunConfig(consensus_axis=None, optimizer="adam"),
                      ShapeConfig("t", 32, 4, "train"))
    struct = tr.state_struct()
    state = tr.init_state(0)
    a = jax.tree.map(lambda s: (s.shape, str(s.dtype)), struct)
    b = jax.tree.map(lambda s: (s.shape, str(jnp.asarray(s).dtype)), state)
    assert jax.tree.all(jax.tree.map(lambda x, y: x == y, a, b))


def test_trainer_ckpt_resume_identical(mesh1, tmp_path):
    """train 6 steps = train 3, checkpoint, restore, train 3 (bitwise, since
    data and RNG derive from (seed, step))."""
    from repro.ckpt import restore, save
    arch = get_smoke("qwen1.5-4b")
    shape = ShapeConfig("t", 32, 4, "train")
    run = RunConfig(consensus_axis=None, optimizer="adam", alpha=0.01)
    tr = make_trainer(mesh1, arch, run, shape)
    data = SyntheticLMData(vocab_size=arch.vocab_size, seq_len=32,
                           global_batch=4)
    step = tr.jit_train_step(donate=False)

    with set_mesh(tr.mesh):
        s_a = tr.init_state(0)
        for i in range(6):
            s_a, _ = step(s_a, data.batch(i))

        s_b = tr.init_state(0)
        for i in range(3):
            s_b, _ = step(s_b, data.batch(i))
        save(tmp_path, 3, s_b)
        s_c, _ = restore(tmp_path, 3, jax.eval_shape(lambda: s_b))
        for i in range(3, 6):
            s_c, _ = step(s_c, data.batch(i))

    for pa, pc in zip(jax.tree.leaves(s_a.x), jax.tree.leaves(s_c.x)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pc))


def test_data_pipeline_determinism_and_noniid():
    d1 = SyntheticLMData(vocab_size=256, seq_len=64, global_batch=8,
                         n_nodes=4, iid=False, seed=3)
    d2 = SyntheticLMData(vocab_size=256, seq_len=64, global_batch=8,
                         n_nodes=4, iid=False, seed=3)
    b1, b2 = d1.batch(17), d2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # non-iid: different nodes see different transition structure
    diid = SyntheticLMData(vocab_size=256, seq_len=64, global_batch=8,
                           n_nodes=4, iid=True, seed=3)
    assert not np.array_equal(diid.batch(17)["tokens"], b1["tokens"])


def test_cells_and_applicability():
    from repro.configs import cells
    all_cells = cells(include_long_skips=True)
    assert len(all_cells) == 40
    runnable = cells()
    skipped = set(all_cells) - set(runnable)
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "qwen1.5-4b", "qwen3-8b", "qwen1.5-32b", "chameleon-34b",
        "llama4-maverick-400b-a17b", "deepseek-v2-lite-16b",
        "seamless-m4t-medium"}


def test_input_specs_shapes():
    for arch_name in ("qwen3-8b", "seamless-m4t-medium"):
        cfg = get_arch(arch_name)
        for sname, shape in SHAPES.items():
            spec = input_specs(cfg, shape)
            if shape.kind == "train":
                assert spec["tokens"].shape == (shape.global_batch,
                                                shape.seq_len)
            if shape.kind == "decode":
                assert spec["tokens"].shape == (shape.global_batch,)
            if cfg.encdec and shape.kind != "decode":
                assert "enc_embeds" in spec
