"""repro.obs: schema round-trips per event type, validation hard-failure
modes, sinks, the counters/spans registries, the Recorder's ledger-first
bits derivation, report/diff regression gating, the obs CLI — and AUDIT
PARITY: on a composed fig6-style dcdgd session (rate-static + budget +
topology switch + fault window), every counter and the cumulative bits
DERIVED from the event log alone must bit-match the live-object audits.
"""
import dataclasses
import json
import types

import numpy as np
import pytest

from repro.obs import (SCHEMA_VERSION, BuildEvent, Counters, CountersEvent,
                       FaultEvent, JsonlSink, MemorySink, NullSink, Recorder,
                       RunManifest, SchemaError, SpanTimer, StepEvent,
                       SwitchEvent, diff, parse_record, provenance,
                       read_events, summarize, validate_record)

ONE_OF_EACH = (
    RunManifest(config={"steps": 4}, wire="int8:block=64", topology="ring",
                seed=0, n_devices=8, jax_version="0.4", backend="cpu"),
    StepEvent(step=3, plan="int8:block=64", bits=1024.0, wall_ms=1.5,
              loss=0.25, snr=40.0, outage=False),
    SwitchEvent(step=5, old="dense", new="ternary:block=64"),
    FaultEvent(step=7, drops=(0, 2)),
    BuildEvent(key="('topo', 'ring', 'dense')", step=0),
    CountersEvent(n_steps=4, counters={"plan_builds": 2},
                  spans={"step": {"total_s": 0.1, "count": 4,
                                  "mean_ms": 25.0}},
                  bank={"builds": 2, "hits": 2, "evictions": 0},
                  wall_s=0.5),
)


# ---------------------------------------------------------------------------
# schema: round-trip + validation failure modes
# ---------------------------------------------------------------------------
class TestEventSchema:
    @pytest.mark.parametrize("ev", ONE_OF_EACH, ids=lambda e: e.KIND)
    def test_round_trip_through_json(self, ev):
        rec = json.loads(json.dumps(ev.to_record()))
        assert rec["kind"] == ev.KIND and rec["v"] == SCHEMA_VERSION
        assert parse_record(rec) == ev

    def test_unknown_kind_is_hard_error(self):
        with pytest.raises(SchemaError, match="unknown event kind"):
            validate_record({"kind": "nope", "v": SCHEMA_VERSION})

    def test_version_mismatch_is_hard_error(self):
        rec = StepEvent(step=0, plan="dense").to_record()
        rec["v"] = SCHEMA_VERSION + 1
        with pytest.raises(SchemaError, match="schema version"):
            validate_record(rec)

    def test_missing_required_field_rejected(self):
        rec = StepEvent(step=0, plan="dense").to_record()
        rec["plan"] = None
        with pytest.raises(SchemaError, match="required field 'plan'"):
            validate_record(rec)
        rec = RunManifest(config={}).to_record()
        rec["n_devices"] = None
        with pytest.raises(SchemaError, match="n_devices"):
            validate_record(rec)

    def test_type_errors_rejected_including_bool_int(self):
        rec = StepEvent(step=0, plan="dense").to_record()
        rec["bits"] = "lots"
        with pytest.raises(SchemaError, match="step.bits"):
            validate_record(rec)
        # bool is an int subclass: an int-typed field must still reject it
        rec = StepEvent(step=0, plan="dense").to_record()
        rec["step"] = True
        with pytest.raises(SchemaError, match="bool"):
            validate_record(rec)

    def test_unknown_extra_keys_tolerated(self):
        # the additive-change side of the version policy
        rec = StepEvent(step=0, plan="dense").to_record()
        rec["a_future_optional_field"] = 42
        validate_record(rec)
        assert parse_record(rec) == StepEvent(step=0, plan="dense")

    def test_read_events_reports_line_numbers(self, tmp_path):
        p = tmp_path / "bad.jsonl"
        good = json.dumps(StepEvent(step=0, plan="dense").to_record())
        p.write_text(good + "\n{not json\n")
        with pytest.raises(SchemaError, match=":2:"):
            read_events(p)
        p.write_text(good + "\n" + json.dumps({"kind": "zap", "v": 1}) + "\n")
        with pytest.raises(SchemaError, match=":2:.*unknown"):
            read_events(p)

    def test_gossip_delay_optional_field(self):
        """gossip_delay is an ADDITIVE optional StepEvent field: stamped
        events round-trip, old records (no key) still parse under the
        SAME schema version, and type errors are rejected."""
        ev = StepEvent(step=3, plan="int8:block=64", gossip_delay=1)
        rec = json.loads(json.dumps(ev.to_record()))
        assert rec["v"] == SCHEMA_VERSION        # no version bump
        assert parse_record(rec) == ev
        assert parse_record(rec).gossip_delay == 1
        # a pre-async log line: same version, key absent
        old = StepEvent(step=3, plan="int8:block=64").to_record()
        old.pop("gossip_delay", None)
        validate_record(old)
        assert parse_record(old).gossip_delay is None
        bad = StepEvent(step=3, plan="dense").to_record()
        bad["gossip_delay"] = "one"
        with pytest.raises(SchemaError, match="gossip_delay"):
            validate_record(bad)

    def test_provenance_block(self):
        prov = provenance()
        assert prov["schema_version"] == SCHEMA_VERSION
        assert prov["jax_version"] and prov["n_devices"] >= 1


# ---------------------------------------------------------------------------
# counters + spans
# ---------------------------------------------------------------------------
class TestCountersSpans:
    def test_counters(self):
        c = Counters()
        assert c.incr("x") == 1 and c.incr("x", 2) == 3
        assert c.get("x") == 3 and c.get("missing") == 0
        c.incr("a")
        assert list(c.as_dict()) == ["a", "x"]      # sorted keys
        c.reset()
        assert c.as_dict() == {}

    def test_span_timer_accumulates_and_sorts(self):
        t = SpanTimer()
        t.add("fast", 0.001)
        t.add("slow", 0.5)
        t.add("slow", 0.5)
        with t.span("ctx"):
            pass
        s = t.summary()
        assert list(s)[0] == "slow"                 # total-descending
        assert s["slow"]["count"] == 2
        assert s["slow"]["total_s"] == pytest.approx(1.0)
        assert s["slow"]["mean_ms"] == pytest.approx(500.0)
        assert s["ctx"]["count"] == 1

    def test_span_timer_overlap_exclusive_total(self):
        """overlap_s subtracts from total_s (the exclusive wall) while
        busy_s keeps the raw busy time — summing phase totals never
        double-counts time hidden under another phase."""
        t = SpanTimer()
        t.add("grad", 1.0)
        t.add("gossip", 0.6, overlap_s=0.4)      # 0.4s hid under grad
        s = t.summary()
        assert s["gossip"]["total_s"] == pytest.approx(0.2)
        assert s["gossip"]["busy_s"] == pytest.approx(0.6)
        assert s["gossip"]["overlap_s"] == pytest.approx(0.4)
        # grad never recorded overlap: no busy_s/overlap_s keys
        assert set(s["grad"]) == {"total_s", "count", "mean_ms"}
        assert s["grad"]["total_s"] + s["gossip"]["total_s"] \
            == pytest.approx(1.2)                # exclusive wall adds up

    def test_span_timer_overlap_clamped_to_span(self):
        t = SpanTimer()
        t.add("a", 0.5, overlap_s=2.0)           # clamp: at most the span
        t.add("b", 0.5, overlap_s=-1.0)          # clamp: never negative
        s = t.summary()
        assert s["a"]["total_s"] == pytest.approx(0.0)
        assert s["a"]["busy_s"] == pytest.approx(0.5)
        assert s["b"]["total_s"] == pytest.approx(0.5)
        assert "busy_s" not in s["b"]

    def test_span_timer_overlap_free_summary_unchanged(self):
        """An overlap-free timer must serialize byte-identically to the
        pre-overlap format (old CountersEvent consumers keep working)."""
        a, b = SpanTimer(), SpanTimer()
        a.add("step", 0.25); a.add("step", 0.25)
        b.add("step", 0.25); b.add("step", 0.25, overlap_s=0.0)
        assert json.dumps(a.summary()) == json.dumps(b.summary())
        assert set(a.summary()["step"]) == {"total_s", "count", "mean_ms"}


# ---------------------------------------------------------------------------
# sinks + recorder derivation rules
# ---------------------------------------------------------------------------
def _plan(outage=False, drops=()):
    return types.SimpleNamespace(outage=outage, drops=tuple(drops))


class TestRecorder:
    def test_jsonl_sink_round_trip(self, tmp_path):
        p = tmp_path / "run.jsonl"
        r = Recorder(JsonlSink(p))
        r.emit_manifest(config={"steps": 2}, topology="ring", seed=7)
        r.on_step(0, _plan(), "dense", {"bits": 256.0, "loss": 1.0})
        r.on_step(1, _plan(drops=(1,)), ("fault", (1,), "dense"),
                  {"bits": 128.0, "loss": 0.5}, wall_ms=2.0)
        r.on_switch(2, "dense", "int8:block=64")
        r.finalize(bank={"builds": 1}, wall_s=0.1, n_steps=2)
        r.close()
        evs = read_events(p)
        kinds = [e.KIND for e in evs]
        assert kinds == ["run_manifest", "step", "fault", "step", "switch",
                         "counters"]
        fault = [e for e in evs if isinstance(e, FaultEvent)][0]
        assert fault.drops == (1,) and isinstance(fault.drops, tuple)
        assert evs[0].seed == 7 and evs[0].jax_version    # auto-filled

    def test_ledger_first_bits_priority(self):
        r = Recorder(MemorySink())
        pol = types.SimpleNamespace(
            spend_log=[(0, 10.0, 0.0, 111.0, "solve"),
                       (1, 10.0, 0.0, 222.0, "hold")],
            counters=None)
        r.bind_policy(pol)
        assert pol.counters is r.counters             # registry shared
        # ledger beats the metrics dict beats the cost_fn
        r.on_step(0, _plan(), "dense", {"bits": 999.0})
        r.on_step(1, _plan(), "dense", None)
        r.on_step(2, _plan(), "dense", {"bits": 333.0})   # no ledger entry
        bits = [e["bits"] for e in r.sink.records if e["kind"] == "step"]
        assert bits == [111.0, 222.0, 333.0]

    def test_cost_fn_fallback_and_unknown(self):
        r = Recorder(MemorySink(), cost_fn=lambda k: {"dense": 64.0}[k])
        r.on_step(0, _plan(), "dense", None)
        r.on_step(1, _plan(), "other", None)          # cost_fn raises -> None
        bits = [e["bits"] for e in r.sink.records]
        assert bits == [64.0, None]

    def test_outage_step_zero_bits_and_counter(self):
        r = Recorder(MemorySink(), cost_fn=lambda k: 1e9)
        r.on_step(0, _plan(outage=True), "outage", {"bits": 555.0})
        rec = r.sink.records[0]
        assert rec["outage"] is True and rec["bits"] == 0.0
        assert r.counters.get("outage_steps") == 1

    def test_nonfinite_metrics_map_to_none(self):
        r = Recorder(MemorySink())
        r.on_step(0, _plan(), "dense",
                  {"loss": float("nan"), "diff_power": 1.0,
                   "noise_power": 0.0})
        rec = r.sink.records[0]
        assert rec["loss"] is None and rec["snr"] is None

    def test_bind_policy_walks_compose_members(self):
        inner = types.SimpleNamespace(counters=None)
        wrapped = types.SimpleNamespace(policy=inner)
        direct = types.SimpleNamespace(counters=None)
        comp = types.SimpleNamespace(members=(wrapped, direct))
        r = Recorder(MemorySink())
        r.bind_policy(comp)
        r.bind_policy(comp)                            # idempotent
        assert inner.counters is r.counters
        assert direct.counters is r.counters

    def test_attach_bank_counts_builds_and_evictions(self):
        from repro.adapt.plan_bank import PlanBank
        bank = PlanBank(build=lambda k: k, max_size=1)
        r = Recorder(MemorySink())
        r.attach_bank(bank)
        r.attach_bank(bank)                            # idempotent
        bank.get("a")
        bank.get("a")                                  # hit: no event
        bank.get("b")                                  # build + evict "a"
        assert r.counters.get("plan_builds") == 2 == bank.builds
        assert r.counters.get("plan_evictions") == 1 == bank.evictions
        builds = [e for e in r.sink.records if e["kind"] == "build"]
        assert [b["key"] for b in builds] == ["a", "b"]

    def test_null_sink_swallows(self):
        r = Recorder(NullSink())
        r.on_step(0, _plan(), "dense", {"bits": 1.0})
        r.finalize()
        r.close()                                      # no error, no output


# ---------------------------------------------------------------------------
# counter mirrors: the audits increment the SHARED registry
# ---------------------------------------------------------------------------
class TestCounterMirrors:
    def test_budget_policy_mirrors_violation_no_bucket(self):
        from repro.adapt.budget import BudgetSchedule
        from repro.adapt.policies import BudgetPolicy
        pol = BudgetPolicy(controller=None, schedule=BudgetSchedule(bits=10.0))
        pol.counters = Counters()
        pol._active_bits = 20.0
        pol._account(0, 10.0, "test")                  # 20 > 10: violation
        pol._active_bits = 5.0
        pol._account(1, 10.0, "test")                  # fits: no increment
        assert pol.counters.get("budget_violations") == 1
        # the same predicate the fig6 post-hoc spend-log audit applies
        posthoc = sum(1 for _, b, _, bits, _ in pol.spend_log
                      if bits > b * (1 + 1e-9))
        assert posthoc == 1

    def test_token_bucket_banked_spend_is_not_a_violation(self):
        from repro.adapt.budget import BudgetSchedule, TokenBucket
        from repro.adapt.policies import BudgetPolicy
        bucket = TokenBucket(capacity=100.0)
        for _ in range(4):
            bucket.fill(10.0)                          # bank 40 bits
        pol = BudgetPolicy(controller=None, schedule=BudgetSchedule(bits=10.0),
                           bucket=bucket)
        pol.counters = Counters()
        pol._active_bits = 25.0                        # > fill, <= balance
        pol._account(0, 10.0, "burst")
        assert pol.counters.get("budget_violations") == 0

    def test_topology_comm_mirrors_eta_min_violation(self):
        from repro.comm import PerLeafPlan, StepTelemetry
        from repro.topology import TopoSchedule, TopologyComm, topology
        sched = TopoSchedule.parse("99:ring:lazy=0.0",
                                   opening="complete:lazy=0.0")
        topos = {sp.canonical(): topology(sp, n=8) for sp in sched.specs()}
        tc = TopologyComm(schedule=sched, topologies=topos, dims=(8,))
        tc.counters = Counters()
        plan = PerLeafPlan.uniform("ternary:block=64")
        d = np.full((1,), 1.0)
        for step in range(3):      # held plan, SNR 0.01 << eta_min = 1.0
            tc.observe(StepTelemetry(step=step, diff_power=d,
                                     noise_power=d / 0.01))
            tc.audit(step, plan)
        assert tc.violations == 1
        assert tc.counters.get("eta_min_violations") == 1


# ---------------------------------------------------------------------------
# report + diff
# ---------------------------------------------------------------------------
def _run_events(bits=100.0, losses=(2.0, 1.0), counters=None, wall=1.0):
    evs = [RunManifest(config={}, n_devices=1, jax_version="0")]
    for i, loss in enumerate(losses):
        evs.append(StepEvent(step=i, plan="dense", bits=bits, loss=loss))
    evs.append(CountersEvent(counters=dict(counters or {}), wall_s=wall))
    return evs


class TestReportDiff:
    def test_summarize_derives_headlines(self):
        evs = list(_run_events(bits=50.0, losses=(3.0, 2.0, 1.0)))
        evs.insert(2, BuildEvent(key="dense"))
        evs.insert(3, SwitchEvent(step=1, old="dense", new="int8"))
        evs.insert(4, FaultEvent(step=1, drops=(0,)))
        rep = summarize(evs)
        d = rep["derived"]
        assert d["n_steps"] == 3 and d["cum_bits"] == 150.0
        assert d["final_loss"] == 1.0 and d["plan_builds"] == 1
        assert d["switches"] == [(1, "dense", "int8")]
        assert d["fault_steps"] == 1 and d["outage_steps"] == 0

    def test_consistency_cross_check(self):
        rep = summarize(_run_events(counters={"plan_builds": 3}))
        assert rep["consistent"] == {"plan_builds": False}   # 0 builds logged

    def test_diff_flags_bits_and_loss_regressions(self):
        a = _run_events(bits=100.0, losses=(2.0, 1.0))
        b = _run_events(bits=150.0, losses=(2.0, 1.2))
        d = diff(a, b, bits_tol=0.01, loss_tol=0.05)
        assert not d["ok"]
        assert any("cum_bits" in r for r in d["regressions"])
        assert any("final_loss" in r for r in d["regressions"])

    def test_diff_strict_counters_any_increase_flags(self):
        a = _run_events(counters={"eta_min_violations": 0})
        b = _run_events(counters={"eta_min_violations": 1})
        d = diff(a, b)
        assert not d["ok"]
        assert any("eta_min_violations 0 -> 1" in r for r in d["regressions"])

    def test_diff_wall_warns_unless_gated(self):
        a = _run_events(wall=1.0)
        b = _run_events(wall=10.0)
        d = diff(a, b)
        assert d["ok"] and any("wall_s" in w for w in d["warnings"])
        assert not diff(a, b, gate_wall=True)["ok"]

    def test_diff_self_is_clean(self):
        a = _run_events(counters={"plan_builds": 0})
        assert diff(a, list(a))["ok"]


# ---------------------------------------------------------------------------
# the obs CLI
# ---------------------------------------------------------------------------
def _write_log(path, **kw):
    r = Recorder(JsonlSink(path))
    r.emit_manifest(config={"x": 1}, seed=0)
    r.on_step(0, _plan(), "dense", {"bits": 10.0, "loss": 1.0})
    r.finalize(n_steps=1, wall_s=0.1)
    r.close()


class TestObsCli:
    def test_validate_report_diff_happy_path(self, tmp_path, capsys):
        from repro.launch import obs_cli
        p = tmp_path / "a.jsonl"
        _write_log(p)
        assert obs_cli.main(["validate", str(p)]) == 0
        assert "valid,v=1" in capsys.readouterr().out
        assert obs_cli.main(["report", str(p), "--json"]) == 0
        rep = json.loads(capsys.readouterr().out)
        assert rep["derived"]["cum_bits"] == 10.0
        assert obs_cli.main(["diff", str(p), str(p)]) == 0

    def test_validate_rejects_missing_manifest(self, tmp_path, capsys):
        from repro.launch import obs_cli
        p = tmp_path / "no_manifest.jsonl"
        r = Recorder(JsonlSink(p))
        r.on_step(0, _plan(), "dense", {"bits": 1.0})
        r.close()
        assert obs_cli.main(["validate", str(p)]) == 1
        assert "INVALID" in capsys.readouterr().out
        assert obs_cli.main(["validate", "--no-manifest", str(p)]) == 0

    def test_validate_rejects_unknown_kind(self, tmp_path, capsys):
        from repro.launch import obs_cli
        p = tmp_path / "bad.jsonl"
        _write_log(p)
        with open(p, "a") as fh:
            fh.write(json.dumps({"kind": "mystery", "v": 1}) + "\n")
        assert obs_cli.main(["validate", str(p)]) == 1

    def test_diff_exit_code_gates_regressions(self, tmp_path, capsys):
        from repro.launch import obs_cli
        a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
        _write_log(a)
        r = Recorder(JsonlSink(b))
        r.emit_manifest(config={"x": 1}, seed=0)
        r.on_step(0, _plan(), "dense", {"bits": 100.0, "loss": 1.0})
        r.finalize(n_steps=1, wall_s=0.1)
        r.close()
        assert obs_cli.main(["diff", str(a), str(b)]) == 1
        assert "OBS-REGRESSION" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# audit parity on a composed fig6-style session: the event log alone
# reproduces every live-object audit, bit for bit
# ---------------------------------------------------------------------------
N, DIM, STEPS, SWITCH = 8, 16, 40, 20
FAULT_WINDOW = (10, 14)
LADDER = ("dense", "int8:block=16", "ternary:block=16")
BUDGET = 3000.0          # affords int8 (~1.1 kbit), never dense (4 kbit)


def _edges(canonical):
    from repro.topology import topology
    W = np.asarray(topology(canonical, n=N).W)
    off = np.abs(W) > 1e-12
    np.fill_diagonal(off, False)
    return int(off.sum()) // 2


@pytest.fixture(scope="module")
def fig6_style_run(tmp_path_factory):
    import jax
    import jax.numpy as jnp

    from repro.adapt import ladder_from_specs
    from repro.adapt.budget import BudgetController, BudgetSchedule
    from repro.adapt.policies import BudgetPolicy
    from repro.adapt.runner import _metric_step, make_dcdgd_session
    from repro.comm import BudgetComm, Compose, FaultComm, StaticComm
    from repro.core import problems
    from repro.core.compressors import Identity, WireCompressor
    from repro.core.wire import make_wire
    from repro.runtime.fault import (OUTAGE_SPEC, drop_renormalize_dense,
                                     peel_plan_key)
    from repro.topology import TopoSchedule, TopologyComm, topology

    prob = problems.quadratic(n_nodes=N, dim=DIM, seed=1)
    sched = TopoSchedule.parse(f"{SWITCH}:torus:4x2,lazy=0.25",
                               opening="ring:lazy=0.0")
    topos = {sp.canonical(): topology(sp, n=N) for sp in sched.specs()}
    opening = sched.active_at(0).canonical()

    wire_ladder = ladder_from_specs(LADDER, level="wire")
    budget_pol = BudgetPolicy(
        controller=BudgetController(ladder=wire_ladder, shapes=((N, DIM),),
                                    neighbors=1,
                                    eta_min=topos[opening].eta_min),
        schedule=BudgetSchedule(bits=BUDGET), cadence=1)
    topo_comm = TopologyComm(
        schedule=sched, topologies=dict(topos), dims=None,
        guaranteed_snr=lambda s: make_wire(s).snr_lower_bound(1))

    class WindowSim:
        def dropped(self, step, n_classes):
            return [0] if FAULT_WINDOW[0] <= step < FAULT_WINDOW[1] else []

    fault_comm = FaultComm(sim=WindowSim(), n_classes=_edges(opening),
                           n_classes_fn=_edges)

    def build_step(key_):
        alpha = lambda t: 0.08 / jnp.sqrt(t)                # noqa: E731
        if key_ == OUTAGE_SPEC:
            return _metric_step(prob, alpha, jnp.eye(N, dtype=jnp.float32),
                                Identity())
        topo_c, drops, inner = peel_plan_key(key_)
        W = topos[topo_c or opening].W
        if drops:
            W = drop_renormalize_dense(W, drops)
        return _metric_step(prob, alpha, jnp.asarray(W, jnp.float32),
                            WireCompressor(fmt=make_wire(inner)))

    log = tmp_path_factory.mktemp("obs") / "run.jsonl"
    recorder = Recorder(JsonlSink(log))
    recorder.emit_manifest(config={"steps": STEPS, "budget": BUDGET},
                           topology=opening, seed=0)
    # bank_size 2 < the 3 distinct plans: the LRU MUST evict, and the
    # event log must count it
    session = make_dcdgd_session(
        prob, topos[opening].W, lambda t: 0.08 / jnp.sqrt(t),
        jax.random.PRNGKey(0), None, bank_size=2, build_step=build_step,
        obs=recorder)
    session.policy = Compose(StaticComm("int8:block=16"),
                             BudgetComm(policy=budget_pol),
                             topo_comm, fault_comm)
    res = session.run(STEPS)
    recorder.close()
    return types.SimpleNamespace(res=res, log=log, recorder=recorder,
                                 budget_pol=budget_pol, topo_comm=topo_comm,
                                 fault_comm=fault_comm)


class TestAuditParity:
    def test_counters_bit_match_live_audits(self, fig6_style_run):
        r = fig6_style_run
        rep = summarize(str(r.log))
        c = rep["counters"]
        # cumulative bits: identical summation order as the live ledger
        ledger_bits = sum(float(e[3]) for e in r.budget_pol.spend_log)
        assert rep["derived"]["cum_bits"] == ledger_bits
        assert rep["derived"]["bits_unknown_steps"] == 0
        # violation counters == the live audit objects
        assert c.get("eta_min_violations", 0) == r.topo_comm.violations == 0
        posthoc = sum(1 for _, b, _, bits, _ in r.budget_pol.spend_log
                      if bits > b * (1 + 1e-9))
        assert c.get("budget_violations", 0) == posthoc == 0
        # bank counters == the bank's own stats (evictions forced)
        assert c["plan_builds"] == r.res.bank_stats["builds"] == 3
        assert c["plan_evictions"] == r.res.bank_stats["evictions"] == 1

    def test_step_stream_matches_session_history(self, fig6_style_run):
        r = fig6_style_run
        rep = summarize(str(r.log))
        d = rep["derived"]
        assert d["n_steps"] == STEPS
        fault_steps = sum(1 for k in r.res.plan_per_step
                          if "fault" in str(k))
        assert d["fault_steps"] == fault_steps == \
            FAULT_WINDOW[1] - FAULT_WINDOW[0]
        assert d["outage_steps"] == 0
        assert sorted(d["distinct_plans"]) == \
            sorted(str(k) for k in set(r.res.plan_per_step))
        # fault-in, fault-out, topo switch
        assert len(d["switches"]) == 3
        assert any("torus" in new for _, _, new in d["switches"])
        assert all(rep["consistent"].values())
        assert rep["manifest"]["topology"].startswith("ring")

    def test_topology_switch_rederived_fault_class_count(self, fig6_style_run):
        # the FaultComm n_classes_fn hook: after the ring -> torus:4x2
        # switch the droppable-class space is the torus's 12 edges, not
        # the ring's 8
        r = fig6_style_run
        assert len(r.topo_comm.switch_log) == 1
        assert r.fault_comm.n_classes == 12

    def test_spans_cover_every_step(self, fig6_style_run):
        rep = summarize(str(fig6_style_run.log))
        spans = rep["spans"]
        assert spans["compile"]["count"] == 3            # == builds
        assert spans["step"]["count"] == STEPS - 3
        assert spans["controller_decide"]["count"] >= STEPS - 1

    def test_log_validates_and_self_diff_is_clean(self, fig6_style_run,
                                                  capsys):
        from repro.launch import obs_cli
        log = str(fig6_style_run.log)
        assert obs_cli.main(["validate", log]) == 0
        capsys.readouterr()
        assert obs_cli.main(["diff", log, log]) == 0
