"""Async delayed gossip: bit-exactness + staleness contracts.

Locks down the one-step-delayed gossip pipeline end to end:

  * ``SnrFloor`` staleness correction: ``eta_min(0)`` equals the base
    Theorem-1 floor on every TopoSpec constructor, the map is monotone
    NONINCREASING in the delay, and ``alpha_max`` shrinks by 1/(1+d);
  * delay=0 async machinery is BIT-EXACT with the sync path under the
    same PRNG key, at every layer: ``dcdgd.delayed_step(carry=None)`` vs
    ``dcdgd.step``, ``delayed_flat_gossip_exchange(carry=None)`` vs
    ``flat_gossip_exchange`` (hypothesis-randomized over wire formats
    and mixed per-leaf rungs, with seeded fallbacks), and the
    shard-mapped wrappers on 8 virtual devices (circulant AND dense
    lowerings);
  * delay=1 sentinel: a differential encoded at step t is mixed exactly
    at step t+1 (the opening carry mixes an exact zero);
  * stale telemetry attribution: the reported powers belong to the
    differential actually mixed (one step stale);
  * a composed delayed session (rate + budget + topology + delay) runs
    with ZERO eta_min/budget violations in the shared obs counters
    registry, delay-tagged plan-bank keys, and delay-stamped step events.
"""
import json
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dcdgd, gossip as G, problems
from repro.core.compressors import Identity, WireCompressor, make_compressor
from repro.core.wire import make_wire
from repro.topology import topology

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


RNG_SPECS = ("int8:block=64", "ternary:block=128",
             "hybrid:block=128,top_j=4", "randk:block=128,k=32")
ALL_SPECS = RNG_SPECS + ("dense", "topk:block=128,k=32")

# every TopoSpec constructor family; n=None where the spec pins n
FLOOR_SPECS = (("ring", 8), ("torus:4x2", None), ("complete", 8),
               ("star", 8), ("erdos:p=0.3,seed=1", 8),
               ("w1", None), ("w2", None))


# ---------------------------------------------------------------------------
# staleness-corrected consensus floor (SnrFloor / alpha_max contracts)
# ---------------------------------------------------------------------------
class TestSnrFloorContract:
    @pytest.mark.parametrize("spec,n", FLOOR_SPECS)
    def test_delay0_equals_base_floor(self, spec, n):
        topo = topology(spec, n=n)
        assert topo.eta_min(0) == float(topo.eta_min)
        assert topo.eta_min() == float(topo.eta_min)

    @pytest.mark.parametrize("spec,n", FLOOR_SPECS)
    def test_monotone_nonincreasing_in_delay(self, spec, n):
        floor = topology(spec, n=n).eta_min
        vals = [floor(d) for d in range(7)]
        for d in range(6):
            assert vals[d + 1] <= vals[d] + 1e-12, (spec, d, vals)
        assert all(v >= 0.0 for v in vals), (spec, vals)

    def test_is_float_and_json_roundtrips(self):
        floor = topology("ring", n=8).eta_min
        assert isinstance(floor, float)
        assert floor + 0.0 == float(floor)        # plain arithmetic works
        assert json.loads(json.dumps({"eta": floor}))["eta"] \
            == pytest.approx(float(floor))

    def test_pickle_preserves_correction_map(self):
        floor = topology("erdos:p=0.3,seed=1", n=8).eta_min
        back = pickle.loads(pickle.dumps(floor))
        assert float(back) == float(floor)
        assert back.lambda_n == floor.lambda_n
        assert back(1) == floor(1) and back(3) == floor(3)

    def test_negative_delay_raises(self):
        floor = topology("ring", n=8).eta_min
        with pytest.raises(ValueError):
            floor(-1)

    def test_alpha_max_shrinks_by_one_over_one_plus_d(self):
        topo = topology("ring", n=8)
        eta, L = 4.0, 2.0
        base = topo.alpha_max(eta, L)
        for d in (1, 2, 5):
            assert topo.alpha_max(eta, L, delay=d) \
                == pytest.approx(base / (1 + d))
        with pytest.raises(ValueError):
            topo.alpha_max(eta, L, delay=-1)

    def test_topology_comm_binds_corrected_floor(self):
        from repro.topology import TopoSchedule, TopologyComm
        topo = topology("ring", n=8)
        sched = TopoSchedule(entries=((0, "ring"),))
        tc = TopologyComm(
            schedule=sched,
            topologies={sched.entries[0][1].canonical(): topo},
            dims=None, gossip_delay=1)
        assert tc.eta_min_at(0) == topo.eta_min(1)
        assert tc.eta_min_at(0) < float(topo.eta_min)
        tc.gossip_delay = 0
        assert tc.eta_min_at(0) == float(topo.eta_min)


# ---------------------------------------------------------------------------
# dcdgd delayed step (paper Alg. 1 under one-step staleness)
# ---------------------------------------------------------------------------
def _w1_setup(comp, alpha=0.02, seed=5):
    topo = topology("w1")
    n = int(topo.W.shape[0])
    prob = problems.quadratic(n_nodes=n, dim=8, seed=2)
    Wj = jnp.asarray(topo.W, jnp.float32)
    params_like = jnp.zeros((n, prob.dim), jnp.float32)
    state = dcdgd.init(prob.grad, params_like, alpha,
                       jax.random.PRNGKey(seed))
    return prob, Wj, state


class TestDcdgdDelayed:
    @pytest.mark.parametrize("comp", [
        Identity(), make_compressor("blocked_hybrid:block=16,top_j=4")],
        ids=["identity", "blocked_hybrid"])
    def test_delay0_bit_exact_with_sync_step(self, comp):
        prob, Wj, st_s = _w1_setup(comp)
        st_d = st_s
        for _ in range(10):
            st_s, aux_s = dcdgd.step(st_s, Wj, prob.grad, 0.02, comp,
                                     track_bits=True)
            st_d, aux_d, _ = dcdgd.delayed_step(st_d, Wj, prob.grad, 0.02,
                                                comp, carry=None,
                                                track_bits=True)
            for a, b in zip(jax.tree.leaves(st_s), jax.tree.leaves(st_d)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            for k in aux_s:
                np.testing.assert_array_equal(np.asarray(aux_s[k]),
                                              np.asarray(aux_d[k]))

    def test_sentinel_mixed_exactly_one_step_late(self):
        """With the exact wire a differential encoded at step t lands at
        t+1: the opening (zero) carry leaves x untouched at step 0, and
        step 1 applies step 0's encode verbatim."""
        comp = Identity()
        prob, Wj, st0 = _w1_setup(comp)
        carry0 = dcdgd.init_delay_carry(comp, st0.x, jax.random.PRNGKey(0),
                                        track_bits=True)
        for leaf in jax.tree.leaves(carry0["c"]):
            np.testing.assert_array_equal(np.asarray(leaf), 0.0)
        st1, _, carry1 = dcdgd.delayed_step(st0, Wj, prob.grad, 0.02, comp,
                                            carry=carry0, track_bits=True)
        # step 0 mixed an exact zero -> x unchanged
        np.testing.assert_array_equal(np.asarray(st1.x), np.asarray(st0.x))
        # the in-flight buffer is exactly C(d_0) = d_0 (Identity)
        np.testing.assert_array_equal(np.asarray(carry1["c"]),
                                      np.asarray(st0.d))
        st2, _, _ = dcdgd.delayed_step(st1, Wj, prob.grad, 0.02, comp,
                                       carry=carry1, track_bits=True)
        # step 1 applies step 0's differential verbatim
        np.testing.assert_array_equal(
            np.asarray(st2.x), np.asarray(st1.x) + np.asarray(st0.d))

    def test_stale_telemetry_attribution(self):
        """Reported powers belong to the differential actually MIXED:
        step 0 of a delayed run reports the zero opening carry."""
        topo = topology("w1")
        prob = problems.quadratic(n_nodes=5, dim=8, seed=2)
        comp = make_compressor("blocked_hybrid:block=16,top_j=4")
        res = dcdgd.run(prob, topo, comp, 0.02, 30, jax.random.PRNGKey(0),
                        gossip_delay=1)
        assert res["differential_power"][0] == 0.0
        assert res["noise_power"][0] == 0.0
        assert res["differential_power"][1] > 0.0

    def test_delayed_run_converges_to_exact_wire_reference(self):
        topo = topology("w1")
        prob = problems.quadratic(n_nodes=5, dim=8, seed=2)
        comp = make_compressor("blocked_hybrid:block=16,top_j=4")
        key = jax.random.PRNGKey(0)
        d1 = dcdgd.run(prob, topo, comp, 0.02, 300, key, gossip_delay=1)
        ref = dcdgd.run(prob, topo, Identity(), 0.02, 300, key,
                        gossip_delay=1)
        assert np.isfinite(d1["f_bar"]).all()
        gap = float(np.mean(d1["f_bar"][-20:])) - prob.f_star
        ref_gap = float(np.mean(ref["f_bar"][-20:])) - prob.f_star
        assert gap <= max(1.5 * ref_gap, ref_gap + 0.05), (gap, ref_gap)

    def test_run_rejects_unsupported_delay(self):
        topo = topology("w1")
        prob = problems.quadratic(n_nodes=5, dim=8, seed=2)
        with pytest.raises(AssertionError):
            dcdgd.run(prob, topo, Identity(), 0.02, 2,
                      jax.random.PRNGKey(0), gossip_delay=2)

    def test_init_delay_carry_reports_zero_power(self):
        carry = dcdgd.init_delay_carry(
            make_compressor("blocked_hybrid:block=16,top_j=4"),
            jnp.zeros((5, 8)), jax.random.PRNGKey(0), track_bits=True)
        assert float(carry["differential_power"]) == 0.0
        assert float(carry["noise_power"]) == 0.0


# ---------------------------------------------------------------------------
# delayed flat exchange: codec-level bit-exactness (single-node plan)
# ---------------------------------------------------------------------------
def _single_node_plan(fmts):
    return G.GossipPlan(consensus_axes=(), dims=(), n_nodes=1,
                        mode="circulant", offsets=(), W=np.ones((1, 1)),
                        fmt=fmts[0], leaf_fmts=tuple(fmts))


def _tree_for(shapes, seed):
    key = jax.random.PRNGKey(seed)
    return {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), s)
            * (1.0 + 3.0 * i) for i, s in enumerate(shapes)}


def check_delay0_matches_flat(shapes, specs, seed):
    """delayed_flat_gossip_exchange(carry=None) == flat_gossip_exchange,
    bit for bit under the same key, and c_fresh == c_own."""
    key = jax.random.PRNGKey(seed)
    d = _tree_for(shapes, seed + 1)
    plan = _single_node_plan([make_wire(s) for s in specs])
    c_sync, agg_sync = G.flat_gossip_exchange(plan, key, d)
    c_own, agg, c_fresh, _, _ = G.delayed_flat_gossip_exchange(
        plan, key, d, carry=None)
    for k in d:
        msg = f"leaf {k} specs {specs} shapes {shapes} seed {seed}"
        np.testing.assert_array_equal(np.asarray(c_sync[k]),
                                      np.asarray(c_own[k]), err_msg=msg)
        np.testing.assert_array_equal(np.asarray(agg_sync[k]),
                                      np.asarray(agg[k]), err_msg=msg)
        np.testing.assert_array_equal(np.asarray(c_own[k]),
                                      np.asarray(c_fresh[k]), err_msg=msg)


def check_sentinel_one_step_late(spec, seed):
    """An encode issued with d_t is returned as c_own at t+1, bit for
    bit; the opening (zero) carry yields an all-zero mix with zero
    reported powers."""
    plan = _single_node_plan([make_wire(spec)])
    key = jax.random.PRNGKey(seed)
    k0, k1, k2 = jax.random.split(key, 3)
    d1 = _tree_for([(96,)], seed + 1)
    d2 = _tree_for([(96,)], seed + 2)
    zeros = jax.tree.map(jnp.zeros_like, d1)
    _, _, _, _, carry0 = G.delayed_flat_gossip_exchange(plan, k0, zeros,
                                                        carry=None)
    c1, agg1, f1, (dp1, np1), carry1 = G.delayed_flat_gossip_exchange(
        plan, k1, d1, carry=carry0)
    np.testing.assert_array_equal(np.asarray(c1["l0"]), 0.0)
    np.testing.assert_array_equal(np.asarray(agg1["l0"]), 0.0)
    assert float(jnp.sum(dp1)) == 0.0 and float(jnp.sum(np1)) == 0.0
    c2, _, _, (dp2, _), _ = G.delayed_flat_gossip_exchange(
        plan, k2, d2, carry=carry1)
    # step 2's mixed decode IS step 1's fresh encode
    np.testing.assert_array_equal(np.asarray(c2["l0"]),
                                  np.asarray(f1["l0"]))
    # ... and its reported power is step 1's differential power
    np.testing.assert_allclose(float(jnp.sum(dp2)),
                               float(jnp.sum(jnp.square(d1["l0"]))),
                               rtol=1e-6)


if HAVE_HYPOTHESIS:
    _last = st.integers(1, 300)
    _lead = st.integers(1, 4)
    _shape = st.one_of(
        st.tuples(_last),
        st.tuples(_lead, _last),
        st.tuples(_lead, st.integers(1, 3), _last),
    )
    _tree = st.lists(st.tuples(_shape, st.sampled_from(ALL_SPECS)),
                     min_size=1, max_size=4)

    @settings(deadline=None)
    @given(tree=_tree, seed=st.integers(0, 2 ** 16 - 1))
    def test_delay0_exchange_bit_exact_property(tree, seed):
        check_delay0_matches_flat([t[0] for t in tree],
                                  [t[1] for t in tree], seed)

    @settings(deadline=None)
    @given(spec=st.sampled_from(ALL_SPECS),
           seed=st.integers(0, 2 ** 16 - 1))
    def test_sentinel_one_step_late_property(spec, seed):
        check_sentinel_one_step_late(spec, seed)


@pytest.mark.parametrize("spec", ALL_SPECS)
def test_delay0_exchange_bit_exact_seeded(spec):
    check_delay0_matches_flat([(96,), (2, 200)], [spec, spec], seed=11)


def test_delay0_exchange_bit_exact_mixed_rungs():
    check_delay0_matches_flat(
        [(96,), (2, 200), (3, 2, 64)],
        ["int8:block=64", "ternary:block=128", "dense"], seed=3)


@pytest.mark.parametrize("spec", RNG_SPECS + ("dense",))
def test_sentinel_one_step_late_seeded(spec):
    check_sentinel_one_step_late(spec, seed=17)


def test_carry_key_replay_is_deterministic():
    """Replaying the carry's stored key over the same differential
    reproduces the in-flight buffer bit-for-bit (the audit contract)."""
    plan = _single_node_plan([make_wire("int8:block=64")])
    key = jax.random.PRNGKey(41)
    d = _tree_for([(2, 200)], 9)
    _, _, _, _, ca = G.delayed_flat_gossip_exchange(plan, key, d, carry=None)
    _, _, _, _, cb = G.delayed_flat_gossip_exchange(plan, ca["key"], d,
                                                    carry=None)
    for a, b in zip(jax.tree.leaves(ca), jax.tree.leaves(cb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# shard-mapped delayed gossip on 8 virtual devices (subprocess)
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
class TestMultideviceDelayed:
    # the sentinel doubles as delay-0 machinery parity: the delayed
    # wrapper's FRESH encode must bit-match the sync wrapper's own decode
    # under the same step key, and land as c_own exactly one step later
    _BODY = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh
        from jax.sharding import PartitionSpec as P
        from repro.core.wire import make_wire
        from repro.core.gossip import (make_plan, build_gossip_fn,
                                       build_delayed_gossip_fn)
        mesh = make_mesh(%(mesh)s)
        fmt = make_wire("int8:block=64")
        plan = make_plan(mesh, %(axes)s, fmt, topology=%(topo)r)
        assert plan.mode == %(mode)r, plan.mode
        axes = %(axes)s
        lead = axes if len(axes) > 1 else axes[0]
        d_specs = {"w": P(lead, None)}
        k = jax.random.PRNGKey(0)
        d1 = {"w": jax.random.normal(jax.random.fold_in(k, 1), (8, 96))}
        d2 = {"w": jax.random.normal(jax.random.fold_in(k, 2), (8, 96))}
        sync = jax.jit(build_gossip_fn(plan, mesh, d_specs))
        init_fn, step_fn = build_delayed_gossip_fn(plan, mesh, d_specs)
        init_fn, step_fn = jax.jit(init_fn), jax.jit(step_fn)
        k0, k1, k2 = jax.random.split(k, 3)
        carry0 = init_fn(k0, d1)
        c1, agg1, f1, (dp1, np1), carry1 = step_fn(k1, d1, carry0)
        # opening carry mixes an exact zero, with zero reported powers
        assert np.array_equal(np.asarray(c1["w"]), 0.0 * np.asarray(c1["w"]))
        assert np.array_equal(np.asarray(agg1["w"]),
                              0.0 * np.asarray(agg1["w"]))
        assert float(jnp.sum(dp1)) == 0.0 and float(jnp.sum(np1)) == 0.0
        # the fresh encode matches the SYNC wrapper under the same key
        c_s1, agg_s1 = sync(k1, d1)
        assert np.array_equal(np.asarray(f1["w"]), np.asarray(c_s1["w"]))
        # ... and is mixed exactly one step later: the decode is bitwise
        # equal; the aggregate only up to compiler reassociation of the
        # decode-axpy (sync and delayed are separately-jitted programs)
        c2, agg2, f2, (dp2, _), carry2 = step_fn(k2, d2, carry1)
        assert np.array_equal(np.asarray(c2["w"]), np.asarray(f1["w"]))
        assert np.allclose(np.asarray(agg2["w"]), np.asarray(agg_s1["w"]),
                           rtol=1e-5, atol=1e-6)
        # stale power attribution: step 2 reports step 1's differential
        ref = float(jnp.sum(jnp.square(d1["w"])))
        assert abs(float(jnp.sum(dp2)) - ref) <= 1e-5 * (ref + 1.0)
        print("OK")
    """

    def test_circulant_lowering_sentinel(self):
        from conftest import run_in_devices
        out = run_in_devices(8, self._BODY % {
            "mesh": '(2, 4), ("pod", "data")',
            "axes": '("pod", "data")', "topo": "ring",
            "mode": "circulant"})
        assert "OK" in out

    def test_dense_lowering_sentinel(self):
        from conftest import run_in_devices
        out = run_in_devices(8, self._BODY % {
            "mesh": '(8,), ("data",)',
            "axes": '("data",)', "topo": "erdos:p=0.4,seed=1",
            "mode": "dense"})
        assert "OK" in out

    def test_trainer_delayed_node_mode(self):
        from conftest import run_in_devices
        out = run_in_devices(8, """
            import jax, numpy as np
            from repro.compat import make_mesh
            from repro.configs import get_smoke
            from repro.configs.base import RunConfig, ShapeConfig
            from repro.data import SyntheticLMData
            from repro.train import make_trainer
            mesh = make_mesh((8, 1), ("data", "model"))
            arch = get_smoke("qwen3-8b")
            run = RunConfig(consensus_axis="data", topology="ring",
                            wire="int8:block=64", gossip_delay=1,
                            alpha=0.02)
            tr = make_trainer(mesh, arch, run,
                              ShapeConfig("t", 64, 8, "train"))
            data = SyntheticLMData(vocab_size=arch.vocab_size, seq_len=64,
                                   global_batch=8)
            state = tr.init_state(0)
            step = tr.train_step_for_wire(("delay", 1, run.wire),
                                          donate=False)
            losses = []
            for i in range(8):
                state, m = step(state, data.batch(i))
                losses.append(float(m["loss"]))
                assert int(m["gossip_delay"]) == 1
                if i == 0:
                    # step 0 mixed the zero opening carry
                    assert float(np.sum(np.asarray(
                        m["diff_power_leaves"]))) == 0.0
            assert np.isfinite(losses).all(), losses
            assert losses[-1] < losses[0], losses
            print("OK", round(losses[0], 3), "->", round(losses[-1], 3))
        """, timeout=560)
        assert "OK" in out


# ---------------------------------------------------------------------------
# composed delayed session: corrected floors, zero violations, obs stamps
# ---------------------------------------------------------------------------
FLEET_N, FLEET_DIM, FLEET_STEPS = 16, 16, 48
FLEET_LADDER = ("dense", "int8:block=64")
FLEET_BUDGET = 20000.0          # affords int8 (~8.7 kbit), never dense


def _delayed_metric_step(problem, alpha_fn, Wj, comp, holder, delay):
    """Session step threading the dcdgd in-flight carry through the shared
    DelayState (the composed DelayComm snapshots exactly what it reads)."""
    @jax.jit
    def one(st, carry):
        a_t = alpha_fn(st.t)
        new_state, aux, carry2 = dcdgd.delayed_step(
            st, Wj, problem.grad, a_t, comp, carry=carry, track_bits=True)
        xbar = jnp.mean(new_state.x, axis=0)
        m = {"f_bar": problem.global_f(xbar),
             "grad_norm_sq": jnp.sum(problem.global_grad(xbar) ** 2),
             "consensus_err": jnp.sum((new_state.x - xbar[None, :]) ** 2)}
        m.update(aux)
        return new_state, m, carry2

    def step(st):
        if holder.carry is None:
            holder.carry = dcdgd.init_delay_carry(
                comp, jax.tree.map(jnp.zeros_like, st.x),
                jax.random.PRNGKey(0), track_bits=True)
            holder.struct = ("dcdgd", int(np.asarray(st.x).shape[0]))
        st2, m, carry2 = one(st, holder.carry)
        holder.carry = carry2
        m = dict(m)
        m["gossip_delay"] = jnp.int32(delay)
        return st2, m

    return step


def build_delayed_fleet(obs_path, topo_spec="erdos:p=0.3,seed=1",
                        n=FLEET_N, steps=FLEET_STEPS, ckpt_dir=None,
                        chaos_schedule=None):
    """A small fig9-shaped composed session: RateComm + BudgetComm +
    TopologyComm + DelayComm, every floor the corrected eta_min(1).
    ``chaos_schedule`` (a FaultSchedule string) additionally composes a
    ChaosComm — slow-link spans scale the budget while the in-flight
    delayed buffer keeps moving."""
    from repro.adapt import ladder_from_specs
    from repro.adapt.budget import BudgetController, BudgetSchedule
    from repro.adapt.controller import RateController
    from repro.adapt.policies import BudgetPolicy, ControllerPolicy
    from repro.adapt.runner import _metric_step, make_dcdgd_session
    from repro.comm import (BudgetComm, Compose, DelayComm, DelayState,
                            RateComm)
    from repro.obs import JsonlSink, Recorder
    from repro.runtime.fault import peel_plan_key
    from repro.topology import TopoSchedule, TopologyComm

    topo = topology(topo_spec, n=n)
    prob = problems.quadratic(n_nodes=n, dim=FLEET_DIM, seed=3)
    Wj = jnp.asarray(topo.W, jnp.float32)
    alpha_fn = lambda t: 0.04 / jnp.sqrt(t)                  # noqa: E731
    holder = DelayState()
    floor = float(topo.eta_min(1))

    def build_step(key_):
        d, k = 0, key_
        if isinstance(k, tuple) and len(k) == 3 and k[0] == "delay":
            d, k = int(k[1]), k[2]
        _, drops, inner = peel_plan_key(k)
        assert not drops, f"no drop faults scheduled, got {key_!r}"
        comp = WireCompressor(fmt=make_wire(inner))
        if d == 0:
            return _metric_step(prob, alpha_fn, Wj, comp)
        return _delayed_metric_step(prob, alpha_fn, Wj, comp, holder, d)

    recorder = Recorder(JsonlSink(obs_path))
    recorder.emit_manifest(
        config={"steps": steps, "budget": FLEET_BUDGET,
                "ladder": list(FLEET_LADDER), "gossip_delay": 1,
                "eta_min_corrected": floor},
        topology=topo.canonical(), seed=0)
    session = make_dcdgd_session(prob, topo.W, alpha_fn,
                                 jax.random.PRNGKey(0), None,
                                 bank_size=2 * len(FLEET_LADDER) + 2,
                                 build_step=build_step, obs=recorder)

    wire_ladder = ladder_from_specs(FLEET_LADDER, level="wire")
    rate = RateComm(
        policy=ControllerPolicy(
            controller=RateController(ladder=wire_ladder, eta_min=floor,
                                      margin=1.25, synthesize_hybrid=False,
                                      level="wire"),
            probe_fn=lambda: np.asarray(session.state.d),
            cadence=8),
        n_leaves=1, cadence=8)
    budget_pol = BudgetPolicy(
        controller=BudgetController(ladder=wire_ladder,
                                    shapes=((n, FLEET_DIM),),
                                    neighbors=1, eta_min=floor),
        schedule=BudgetSchedule(bits=FLEET_BUDGET), cadence=1)
    topo_sched = TopoSchedule(entries=((0, topo_spec),))
    topo_comm = TopologyComm(
        schedule=topo_sched,
        topologies={topo_sched.entries[0][1].canonical(): topo},
        dims=None,
        guaranteed_snr=lambda s: make_wire(s).snr_lower_bound(1))
    members = [rate, BudgetComm(policy=budget_pol), topo_comm,
               DelayComm(delay=1, state=holder)]
    if chaos_schedule is not None:
        from repro.runtime.chaos import ChaosComm, FaultSchedule
        n_edges = int(np.asarray(topo.adj).sum()) // 2
        members.append(ChaosComm(schedule=FaultSchedule.parse(
            chaos_schedule), n_edges=n_edges))
    policy = Compose(*members)
    session.policy = policy
    if ckpt_dir is not None:
        from repro.comm import SessionCheckpointer
        session.checkpoint = SessionCheckpointer(
            directory=str(ckpt_dir), policy=policy, every=4, retain=0)
    return {"session": session, "policy": policy, "topo_comm": topo_comm,
            "budget_pol": budget_pol, "recorder": recorder, "prob": prob,
            "topo": topo, "holder": holder, "steps": steps}


@pytest.fixture(scope="module")
def delayed_fleet(tmp_path_factory):
    log = tmp_path_factory.mktemp("async_fleet") / "fleet.jsonl"
    fleet = build_delayed_fleet(str(log))
    res = fleet["session"].run(fleet["steps"])
    fleet["recorder"].close()
    return {"res": res, "log": str(log), **fleet}


class TestComposedDelayedSession:
    def test_zero_violation_counters(self, delayed_fleet):
        from repro.obs import summarize
        rep = summarize(delayed_fleet["log"])
        counters = dict(rep["counters"])
        assert counters.get("eta_min_violations", 0) == 0, counters
        assert counters.get("budget_violations", 0) == 0, counters
        assert delayed_fleet["topo_comm"].violations == 0
        bp = delayed_fleet["budget_pol"]
        assert not any(bits > b * (1 + 1e-9)
                       for _, b, _, bits, _ in bp.spend_log)

    def test_plan_keys_are_delay_tagged(self, delayed_fleet):
        keys = set(delayed_fleet["res"].plan_per_step)
        assert keys, "no plans recorded"
        for k in keys:
            assert isinstance(k, tuple) and k[0] == "delay" and k[1] == 1, k

    def test_budget_holds_session_on_cheap_rung(self, delayed_fleet):
        # dense (~8 kbit) exceeds the 4 kbit cap: every decided plan must
        # be the int8 rung
        inner = {k[2] for k in delayed_fleet["res"].plan_per_step}
        assert all("int8" in str(i) for i in inner), inner

    def test_step_events_stamp_gossip_delay(self, delayed_fleet):
        from repro.obs import read_events, summarize
        steps = [e for e in read_events(delayed_fleet["log"])
                 if e.KIND == "step"]
        assert len(steps) == delayed_fleet["steps"]
        assert all(e.gossip_delay == 1 for e in steps)
        rep = summarize(delayed_fleet["log"])
        assert all(rep["consistent"].values()), rep["consistent"]

    def test_fleet_converges_under_corrected_floor(self, delayed_fleet):
        hist = delayed_fleet["res"].metrics_arrays()
        prob = delayed_fleet["prob"]
        assert np.isfinite(hist["f_bar"]).all()
        assert float(hist["f_bar"][-1]) - prob.f_star \
            < float(hist["f_bar"][0]) - prob.f_star

    def test_floor_pushed_is_corrected_one(self, delayed_fleet):
        tc = delayed_fleet["topo_comm"]
        topo = delayed_fleet["topo"]
        assert tc.gossip_delay == 1             # Compose copied the delay
        assert tc.eta_min_at(0) == topo.eta_min(1)
        assert tc.eta_min_at(0) < float(topo.eta_min)


# ---------------------------------------------------------------------------
# trainer-facing validation (single device: raises before any mesh work)
# ---------------------------------------------------------------------------
class TestTrainerDelayValidation:
    def _make(self, **run_kw):
        from repro.compat import make_mesh
        from repro.configs import get_smoke
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.train import make_trainer
        mesh = make_mesh((1, 1), ("data", "model"))
        run = RunConfig(consensus_axis="data", wire="int8:block=64",
                        alpha=0.02, **run_kw)
        return make_trainer(mesh, get_smoke("qwen3-8b"), run,
                            ShapeConfig("t", 64, 8, "train"))

    def test_delay_out_of_range_raises(self):
        with pytest.raises(ValueError, match="must be 0 or 1"):
            self._make(gossip_delay=2)

    def test_delay_incompatible_with_gossip_stream(self):
        with pytest.raises(ValueError, match="gossip_stream"):
            self._make(gossip_delay=1, gossip_stream=True)

    def test_delay_needs_flat_wire_path(self):
        with pytest.raises(ValueError, match="wire_path"):
            self._make(gossip_delay=1, wire_path="leaf")

    def test_delay_needs_consensus_graph(self):
        with pytest.raises(ValueError, match="consensus"):
            self._make(gossip_delay=1)
