"""Stateful structured compression (ISSUE 10): the lowrank wire family
and the innovation-compression rung.

Locks down the new-subsystem contracts end to end:

  * LowRankWire codec: roundtrip determinism, exact ``wire_bits``
    payload accounting, ``per_leaf_flat_bits`` decomposition on mixed
    flat plans, and the EXACT ``expected_noise_power`` oracle
    (Monte-Carlo-validated like the PR-1 oracle tests — the codec is
    deterministic, so the MC mean must match identically);
  * the stateful gossip carry: cold-start bit-parity with the stateless
    flat path, warm-start residual improvement on slowly varying
    differentials, and the cold flush value;
  * WireSpec grammar errors: an unknown family raises with the full
    catalog (every family name + parameter grammar), and every
    defaults-complete grammar line round-trips through
    ``WireSpec.parse``;
  * resume kind "wire-state": snapshot/restore of a live WireStateComm
    is bit-exact, and ElasticComm-style churn (``set_shapes``) flushes
    the carry;
  * the innovation rung (core.innovation): oracle identity on the
    innovation differential (MC, tolerance-gated), convergence on the
    W1 quadratic, the hw = (W (x) I) h invariant, and RunConfig /
    session dispatch (``algorithm="innovation"``).
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import WireSpec, WireState, WireStateComm, describe_families
from repro.core import gossip as G
from repro.core import innovation, problems
from repro.core.compressors import Identity, WireCompressor, make_compressor
from repro.core.wire import make_flat_plan, make_wire, per_leaf_flat_bits
from repro.lowrank import (LowRankWire, init_wire_state,
                           stateful_flat_gossip_exchange)
from repro.lowrank.wire import tile_dims
from repro.topology import topology


def _single_node_plan(fmts):
    return G.GossipPlan(consensus_axes=(), dims=(), n_nodes=1,
                        mode="circulant", offsets=(), W=np.ones((1, 1)),
                        fmt=fmts[0], leaf_fmts=tuple(fmts))


# ---------------------------------------------------------------------------
# codec geometry + bit accounting
# ---------------------------------------------------------------------------
class TestLowRankCodec:
    def test_tile_dims(self):
        assert tile_dims(512) == (16, 32)
        assert tile_dims(64) == (8, 8)
        assert tile_dims(16) == (4, 4)

    def test_rank_range_validated(self):
        with pytest.raises(ValueError, match="out of range"):
            make_wire("lowrank:block=16,r=5")       # tile 4x4 caps r at 4
        with pytest.raises(ValueError, match="iters"):
            make_wire("lowrank:r=2,iters=0")

    def test_wire_bits_matches_actual_payload(self):
        fmt = make_wire("lowrank:block=64,r=3")
        for shape in [(64,), (200,), (3, 130)]:
            z = jax.random.normal(jax.random.PRNGKey(0), shape)
            wire = fmt.encode(jax.random.PRNGKey(1), z)
            actual = sum(int(np.prod(w.shape)) * w.dtype.itemsize * 8
                         for w in jax.tree.leaves(wire))
            assert actual == fmt.wire_bits(shape), (shape, actual)

    def test_bits_linear_in_rank_not_dim(self):
        # the whole point of the family: payload scales with r, and
        # per-element cost FALLS as the block grows (r(m+n)/mn)
        b512 = make_wire("lowrank:block=512,r=4").wire_bits((512,))
        assert b512 == 4 * (16 + 32) * 32
        assert make_wire("lowrank:block=512,r=2").wire_bits((512,)) \
            == b512 // 2

    def test_roundtrip_deterministic_and_zero_maps_to_zero(self):
        fmt = make_wire("lowrank:block=64,r=2")
        z = jax.random.normal(jax.random.PRNGKey(3), (3, 130))
        a = fmt.decode(fmt.encode(jax.random.PRNGKey(0), z), z.shape,
                       jnp.float32)
        b = fmt.decode(fmt.encode(jax.random.PRNGKey(9), z), z.shape,
                       jnp.float32)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        zero = jnp.zeros((2, 64))
        dec = fmt.decode(fmt.encode(jax.random.PRNGKey(0), zero),
                         zero.shape, jnp.float32)
        np.testing.assert_array_equal(np.asarray(dec), 0.0)

    def test_full_rank_tile_is_exact(self):
        fmt = make_wire("lowrank:block=16,r=4")     # tile 4x4, r = m: exact
        z = jax.random.normal(jax.random.PRNGKey(5), (48,))
        dec = fmt.decode(fmt.encode(jax.random.PRNGKey(0), z), z.shape,
                         jnp.float32)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(z),
                                   rtol=1e-5, atol=1e-5)
        power = float(jnp.sum(z ** 2))
        assert float(fmt.expected_noise_power(z)) <= 1e-5 * power

    def test_per_leaf_flat_bits_decomposition(self):
        shapes = [(3, 70), (200,), (2, 128)]
        fmts = [make_wire("int8:block=64"), make_wire("lowrank:block=64,r=3"),
                make_wire("ternary:block=128")]
        make_flat_plan(shapes, [jnp.float32] * 3, fmts)   # mixed plan builds
        per = per_leaf_flat_bits(fmts, shapes)
        assert len(per) == 3 and all(b > 0 for b in per)
        from repro.core.wire import flat_tree_wire_bits
        assert sum(per) == flat_tree_wire_bits(fmts, shapes)


# ---------------------------------------------------------------------------
# the exact oracle, Monte-Carlo-gated (deterministic codec -> identity)
# ---------------------------------------------------------------------------
N_MC = 16


@pytest.mark.parametrize("spec", ["lowrank:block=64,r=1",
                                  "lowrank:block=64,iters=3,r=4",
                                  "lowrank:block=16,r=2"])
@pytest.mark.parametrize("shape", [(64,), (257,), (3, 130), (2, 2, 100)])
def test_lowrank_oracle_mc(spec, shape):
    fmt = make_wire(spec)
    z = jax.random.normal(jax.random.PRNGKey(11), shape) * 2.0
    pred = float(fmt.expected_noise_power(z))
    keys = jax.random.split(jax.random.PRNGKey(12), N_MC)

    def one(k):
        dec = fmt.decode(fmt.encode(k, z), z.shape, jnp.float32)
        return jnp.sum((dec - z.astype(jnp.float32)) ** 2)

    draws = np.asarray(jax.vmap(one)(keys), np.float64)
    mc, se = float(draws.mean()), float(draws.std() / np.sqrt(N_MC))
    power = float(jnp.sum(z.astype(jnp.float32) ** 2))
    assert abs(mc - pred) <= 6.0 * se + 1e-5 * (power + 1.0), \
        (spec, shape, mc, pred)
    assert se <= 1e-9 * (power + 1.0)       # deterministic: zero variance


def test_innovation_oracle_mc():
    """The innovation rung's oracle IS comp.expected_noise_power on the
    innovation differential: measured residual must sit within the MC
    tolerance after the state has moved away from zero."""
    topo = topology("w1")
    prob = problems.quadratic(n_nodes=5, dim=8, seed=2)
    comp = make_compressor("lowprec:bits=4")
    Wj = jnp.asarray(topo.W, jnp.float32)
    st = innovation.init(jnp.zeros((5, 8), jnp.float32),
                         jax.random.PRNGKey(1))
    for _ in range(20):
        st, _ = innovation.step(st, Wj, prob.grad, 0.05, comp, 0.3)
    d = innovation.innovation_differential(st, prob.grad, 0.05)
    flat = np.asarray(d).reshape(5, -1)
    pred = float(sum(comp.expected_noise_power(jnp.asarray(r))
                     for r in flat))
    keys = jax.random.split(jax.random.PRNGKey(7), 400)
    draws = np.array([
        float(sum(jnp.sum((comp(k, jnp.asarray(r)) - jnp.asarray(r)) ** 2)
                  for r in flat)) for k in keys])
    mc, se = float(draws.mean()), float(draws.std() / np.sqrt(len(draws)))
    assert pred > 0.0
    assert abs(mc - pred) <= 6.0 * se + 1e-6 * (pred + 1.0), (mc, pred, se)


# ---------------------------------------------------------------------------
# stateful gossip carry
# ---------------------------------------------------------------------------
class TestStatefulExchange:
    def _plan_and_tree(self):
        fmts = [make_wire("lowrank:block=64,r=2"), make_wire("int8:block=64")]
        plan = _single_node_plan(fmts)
        key = jax.random.PRNGKey(0)
        d = {"a": jax.random.normal(jax.random.fold_in(key, 1), (3, 130)),
             "b": jax.random.normal(jax.random.fold_in(key, 2), (64,))}
        return plan, key, d

    def test_cold_start_bit_exact_with_stateless_flat_path(self):
        plan, key, d = self._plan_and_tree()
        c_ref, agg_ref = G.flat_gossip_exchange(plan, key, d)
        c, agg, ws = stateful_flat_gossip_exchange(plan, key, d, None)
        for k in d:
            np.testing.assert_array_equal(np.asarray(c_ref[k]),
                                          np.asarray(c[k]), err_msg=k)
            np.testing.assert_array_equal(np.asarray(agg_ref[k]),
                                          np.asarray(agg[k]), err_msg=k)
        # exactly the lowrank group carries state
        assert set(ws) == {"q"} and len(ws["q"]) == 1

    def test_warm_start_reduces_residual(self):
        plan, key, d1 = self._plan_and_tree()
        d2 = jax.tree.map(
            lambda t: t + 0.01 * jax.random.normal(
                jax.random.PRNGKey(9), t.shape), d1)
        _, _, ws1 = stateful_flat_gossip_exchange(plan, key, d1, None)
        c_warm, _, _ = stateful_flat_gossip_exchange(plan, key, d2, ws1)
        c_cold, _, _ = stateful_flat_gossip_exchange(plan, key, d2, None)

        def resid(c):
            return float(sum(jnp.sum((c[k] - d2[k]) ** 2) for k in d2))

        assert resid(c_warm) <= resid(c_cold) + 1e-6

    def test_init_wire_state_is_cold_flush_value(self):
        plan, key, d = self._plan_and_tree()
        shapes = [d["a"].shape, d["b"].shape]
        ws = init_wire_state(plan, shapes, [jnp.float32, jnp.float32])
        fmt = plan.leaf_fmts[0]
        (gi, q0), = ws["q"].items()
        # every tile holds the SAME fixed orthonormal seed
        q = np.asarray(q0)
        np.testing.assert_array_equal(q, np.broadcast_to(q[:1, :1], q.shape))
        np.testing.assert_allclose(
            np.einsum("ki,kj->ij", q[0, 0], q[0, 0]),
            np.eye(fmt.r), atol=1e-6)


# ---------------------------------------------------------------------------
# WireSpec grammar catalog (satellite 1) — error text round-trips
# ---------------------------------------------------------------------------
class TestGrammarCatalog:
    def test_unknown_family_lists_catalog(self):
        with pytest.raises(ValueError) as ei:
            WireSpec.parse("nosuchcodec:r=3")
        text = str(ei.value)
        assert "nosuchcodec" in text
        for name in ("dense", "int8", "ternary", "hybrid", "lowrank",
                     "identity", "sparsifier", "outage"):
            assert name in text, name
        assert "lowrank[:r=4,iters=1,block=512]" in text

    def test_catalog_grammar_lines_round_trip(self):
        """Every defaults-complete grammar entry in the catalog must
        itself parse — the error text can never advertise a spelling the
        parser rejects."""
        text = describe_families()
        m = {level: body for level, _, body in
             (ln.strip().partition(": ") for ln in text.splitlines())
             if level in ("wire", "compressor")}
        assert m["wire"] and m["compressor"]
        checked = 0
        for level, body in m.items():
            for ent in body.split("; "):
                g = re.fullmatch(r"(\w+)(?:\[:(.*)\])?", ent.strip())
                assert g, ent
                name, params = g.group(1), g.group(2) or ""
                if "<required>" in params or "=..." in params:
                    continue                 # not spellable from defaults
                spec = name + (":" + params if params else "")
                ws = WireSpec.parse(spec)
                assert ws.name == name
                assert WireSpec.parse(ws.canonical()) == ws
                ws.codec("wire" if level == "wire" else "compressor")
                checked += 1
        assert checked >= 8, checked

    def test_lowrank_spec_canonical_and_builds(self):
        ws = WireSpec.parse("lowrank:r=4,iters=2")
        assert ws.canonical() == "lowrank:iters=2,r=4"
        fmt = ws.wire()
        assert isinstance(fmt, LowRankWire)
        assert (fmt.r, fmt.iters, fmt.block) == (4, 2, 512)
        comp = WireSpec.parse("wire:lowrank:r=2").compressor()
        assert isinstance(comp, WireCompressor)


# ---------------------------------------------------------------------------
# resume kind "wire-state" + churn flush
# ---------------------------------------------------------------------------
class TestWireStateResume:
    def _live_member(self):
        m = WireStateComm()
        fmt = make_wire("lowrank:block=64,r=2")
        q = np.asarray(jax.random.normal(jax.random.PRNGKey(3),
                                         (4, 2, 8, 2)), np.float32)
        m.state.carry = {"q": {1: jnp.asarray(q)}}
        m.state.struct = ("lowrank:r=2", "circulant", (((0,), 1.0),))
        return m, q

    def test_snapshot_restore_bit_exact(self):
        import json

        from repro.comm.resume import _restore_member, _snap_member
        m, q = self._live_member()
        snap = json.loads(json.dumps(_snap_member(m)))   # JSON-safe
        assert snap["kind"] == "wire-state"
        fresh = WireStateComm()
        _restore_member(fresh, snap)
        assert fresh.state.struct == m.state.struct
        np.testing.assert_array_equal(
            np.asarray(fresh.state.carry["q"][1]), q)
        assert 1 in fresh.state.carry["q"]               # int key survived

    def test_snapshot_none_carry(self):
        from repro.comm.resume import _restore_member, _snap_member
        m = WireStateComm()
        snap = _snap_member(m)
        assert snap["kind"] == "wire-state" and snap["carry"] is None
        fresh, _ = self._live_member()
        _restore_member(fresh, snap)
        assert fresh.state.carry is None and fresh.state.struct is None

    def test_compose_policy_snapshot_includes_wire_state(self):
        from repro.comm import Compose, StaticComm
        from repro.comm.resume import restore_policy, snapshot_policy
        m, q = self._live_member()
        pol = Compose(StaticComm("lowrank:r=2"), m)
        snap = snapshot_policy(pol)
        m2 = WireStateComm()
        pol2 = Compose(StaticComm("lowrank:r=2"), m2)
        restore_policy(pol2, snap)
        np.testing.assert_array_equal(np.asarray(m2.state.carry["q"][1]), q)
        assert m2.state.struct == m.state.struct

    def test_churn_set_shapes_flushes(self):
        m, _ = self._live_member()
        m.set_shapes([(16, 8)])          # ElasticComm pushes new shapes
        assert m.state.carry is None and m.state.struct is None

    def test_passive_policy_surface(self):
        m, _ = self._live_member()
        assert m.decide(0) is None and m.decide(100) is None
        m.observe(None)                  # no-op by contract
        assert not m.consumes_telemetry
        assert not hasattr(m, "pre_decide")   # must stay a plain proposer


# ---------------------------------------------------------------------------
# innovation rung: dynamics + RunConfig/session plumbing
# ---------------------------------------------------------------------------
class TestInnovationRung:
    def test_hw_invariant(self):
        topo = topology("w1")
        prob = problems.quadratic(n_nodes=5, dim=8, seed=2)
        Wj = jnp.asarray(topo.W, jnp.float32)
        st = innovation.init(jnp.zeros((5, 8), jnp.float32),
                             jax.random.PRNGKey(0))
        comp = make_compressor("lowprec:bits=8")
        for _ in range(10):
            st, _ = innovation.step(st, Wj, prob.grad, 0.05, comp, 0.4)
        np.testing.assert_allclose(np.asarray(Wj @ st.h), np.asarray(st.hw),
                                   rtol=1e-5, atol=1e-5)

    def test_converges_on_w1_quadratic(self):
        topo = topology("w1")
        prob = problems.quadratic(n_nodes=5, dim=8, seed=2)
        res = innovation.run(prob, topo, make_compressor("lowprec:bits=8"),
                             0.05, 400, jax.random.PRNGKey(0), gamma=0.5)
        gap0 = res["f_bar"][0] - prob.f_star
        gapT = res["f_bar"][-1] - prob.f_star
        assert np.isfinite(res["f_bar"]).all()
        assert gapT < 0.1 * gap0, (gap0, gapT)
        # self-annealing: late noise power far below early
        assert res["noise_power"][-10:].mean() \
            < 1e-3 * max(res["noise_power"][:10].mean(), 1e-12) + 1e-12

    def test_lowrank_wire_rides_innovation(self):
        topo = topology("w1")
        prob = problems.quadratic(n_nodes=5, dim=16, seed=4)
        comp = WireCompressor(fmt=make_wire("lowrank:block=16,r=2"))
        res = innovation.run(prob, topo, comp, 0.05, 300,
                             jax.random.PRNGKey(0), gamma=0.3)
        assert np.isfinite(res["f_bar"]).all()
        assert res["f_bar"][-1] < res["f_bar"][0]
        assert res["cum_bits"][-1] > 0

    def test_choco_gamma_properties(self):
        topo = topology("w1")
        g_inf = innovation.choco_gamma(topo.W, float("inf"))
        g_lo = innovation.choco_gamma(topo.W, 2.0)
        assert 0.0 < g_lo <= g_inf < 1.0

    def test_runconfig_algorithm_validation(self):
        from repro.configs.base import RunConfig
        assert RunConfig(algorithm="innovation").algorithm == "innovation"
        with pytest.raises(ValueError, match="unknown algorithm"):
            RunConfig(algorithm="nope")
        with pytest.raises(ValueError, match="innovation_gamma"):
            RunConfig(innovation_gamma=-1.0)

    def test_session_for_algorithm_dispatch(self):
        from repro.adapt.runner import session_for_algorithm
        from repro.comm import StaticComm
        from repro.configs.base import RunConfig
        from repro.core import dcdgd
        topo = topology("w1")
        prob = problems.quadratic(n_nodes=5, dim=8, seed=2)
        for algo, state_t in (("dcdgd", dcdgd.DCDGDState),
                              ("innovation", innovation.InnovationState)):
            run = RunConfig(algorithm=algo, innovation_gamma=0.4)
            sess = session_for_algorithm(
                run, prob, topo.W, 0.05, jax.random.PRNGKey(0),
                StaticComm("identity"))
            assert isinstance(sess.state, state_t), algo
            res = sess.run(5)
            assert np.isfinite(res.metrics_arrays()["f_bar"]).all()
