#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md), with the dev deps the suite expects.
#
#   scripts/run_tests.sh            # full tier-1 suite
#   scripts/run_tests.sh --fast     # CPU-only split (-m "not multidevice"),
#                                   # stays under ~5 minutes
#   scripts/run_tests.sh --hypothesis   # property-test split only: seeded
#                                   # (--hypothesis-seed=0) and bounded via
#                                   # the derandomized "repro-ci" profile
#                                   # (tests/conftest.py), so it is
#                                   # deterministic and wall-time-bounded
#   scripts/run_tests.sh --cli-smoke    # launch/train.py --smoke once per
#                                   # comm-policy class (static / adapt /
#                                   # budget / composed / topology /
#                                   # chaos / lowrank), 8 virtual CPU
#                                   # devices; fails on nonzero exit,
#                                   # missing metrics keys, or a repro.obs
#                                   # event log that does not validate
#                                   # (unknown event kinds / missing
#                                   # manifest fields)
#   scripts/run_tests.sh <pytest args...>   # passthrough
set -euo pipefail
cd "$(dirname "$0")/.."

# dev deps (hypothesis etc.) — tests degrade to skips without them, so a
# failed install is a warning, not an error (containers may be offline)
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "WARN: pip install -r requirements-dev.txt failed (offline?); " \
            "hypothesis-based property tests will be skipped"

ARGS=("$@")
if [[ "${1:-}" == "--fast" ]]; then
    ARGS=(-m "not multidevice" "${@:2}")
elif [[ "${1:-}" == "--hypothesis" ]]; then
    # the property-test files; seeded + derandomized profile => tier-1
    # deterministic.  Without hypothesis installed the files degrade to
    # their seeded fallback tests (and --hypothesis-seed would be an
    # unknown flag), so only pass the seed when the plugin is present.
    ARGS=(tests/test_wire_properties.py tests/test_compressors.py
          tests/test_consensus_greedy.py tests/test_async_gossip.py
          "${@:2}")
    if python -c "import hypothesis" 2>/dev/null; then
        ARGS+=(--hypothesis-seed=0)
    else
        echo "WARN: hypothesis not installed; running seeded fallbacks only"
    fi
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        exec python -m pytest -x -q "${ARGS[@]}"
elif [[ "${1:-}" == "--cli-smoke" ]]; then
    # one end-to-end launcher run per comm-policy class, all through the
    # same TrainSession driver; the checker fails the split when a run
    # exits nonzero, writes no metrics rows, or drops a required key
    export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
    TMP="$(mktemp -d)"
    trap 'rm -rf "$TMP"' EXIT
    LADDER="dense;int8:block=64;ternary:block=64"
    COMMON=(--arch qwen3-8b --smoke --steps 6 --seq-len 64 --global-batch 8
            --optimizer sgd --alpha 0.05 --log-every 2 --adapt-interval 2
            --adapt-ladder "$LADDER")
    modes=(static adapt budget composed topology chaos async lowrank)
    declare -A FLAGS=(
        [static]=""
        [adapt]="--adapt"
        [budget]="--bit-budget 1200000 --token-bucket"
        [composed]="--adapt --compose --bit-budget 1200000 --outage-windows 2-4"
        # time-varying topology: torus:4x2 (dense lowering on the linear
        # 8-node mesh) -> ring (circulant) at step 3, composed with rate +
        # hard budget + per-edge faults; the checker additionally gates on
        # eta_min_violations == 0 (the TopologyComm retarget audit)
        [topology]="--mesh 8x1 --adapt --compose --bit-budget 2400000
                    --topology torus:4x2 --topo-schedule 3:ring
                    --edge-drop-prob 0.2"
        # scripted faults + crash-consistent checkpointing: a slow-link
        # span scales the composed budget, an outage window blacks out a
        # step, and SessionCheckpointer snapshots policy state alongside
        # the model; the checker additionally gates on zero eta_min /
        # budget violation counters in the event log.  NOTE the --chaos
        # value must stay space-free: ${FLAGS[$mode]} expands unquoted.
        [chaos]="--adapt --compose --bit-budget 1200000 --token-bucket
                 --chaos slow:edge=0-1,span=2:4,factor=0.5|outage:span=4:5
                 --ckpt-every 3 --ckpt-dir $TMP/chaos-ckpt"
        # async delayed gossip: one-step-stale exchange through the
        # composed rate + budget session; controllers retarget against
        # the staleness-corrected floor eta_min(1).  The checker gates on
        # zero eta_min/budget violation counters and on every step event
        # carrying gossip_delay=1 (the stale-attribution stamp).
        [async]="--gossip-delay 1 --adapt --compose --bit-budget 1200000"
        # the stateful structured rung: adaptation over a ladder that
        # includes lowrank:r=4 (warm power-iteration factors threaded
        # through the trainer's stateful gossip carry), checkpointing
        # every 2 steps; a second --resume invocation below extends the
        # run and the checker gates on checkpoint presence plus zero
        # eta_min violations across BOTH runs
        [lowrank]="--adapt --adapt-ladder dense;int8:block=64;lowrank:r=4
                   --ckpt-every 2 --ckpt-dir $TMP/lowrank-ckpt"
    )
    rc=0
    for mode in "${modes[@]}"; do
        echo "== cli-smoke: $mode =="
        # shellcheck disable=SC2086
        if ! PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
                python -m repro.launch.train "${COMMON[@]}" ${FLAGS[$mode]} \
                --metrics-out "$TMP/$mode.json" \
                --obs "$TMP/$mode.jsonl"; then
            echo "cli-smoke $mode: FAIL (nonzero exit)"; rc=1; continue
        fi
        # the emitted event log must be schema-valid: every line a known
        # v=SCHEMA_VERSION event, first event a run_manifest with its
        # required fields (obs_cli exits nonzero otherwise)
        if ! PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
                python -m repro.launch.obs_cli validate "$TMP/$mode.jsonl"; then
            echo "cli-smoke $mode: FAIL (obs validate)"; rc=1; continue
        fi
        if [[ "$mode" == chaos ]]; then
            # the run must have checkpointed, injected the scripted faults,
            # and closed with zero violation counters (counters only emits
            # touched counters — absent means zero, hence .get)
            if ! python - "$TMP/$mode.jsonl" "$TMP/chaos-ckpt" <<'PY'
import json, pathlib, sys
recs = [json.loads(l) for l in open(sys.argv[1])]
counters = next(r["counters"] for r in recs if r.get("kind") == "counters")
for name in ("eta_min_violations", "budget_violations"):
    assert counters.get(name, 0) == 0, f"{name}: {counters[name]}"
assert counters.get("fault_injections", 0) >= 1, counters
assert counters.get("outage_steps", 0) == 1, counters
assert list(pathlib.Path(sys.argv[2]).glob("step_*")), "no checkpoint"
print(f"cli-smoke chaos: counters OK {counters}")
PY
            then
                echo "cli-smoke $mode: FAIL (chaos counters)"; rc=1; continue
            fi
        fi
        if [[ "$mode" == async ]]; then
            # delayed run: zero violation counters against the corrected
            # floor, and every step event stamped gossip_delay=1
            if ! python - "$TMP/$mode.jsonl" <<'PY'
import json, sys
recs = [json.loads(l) for l in open(sys.argv[1])]
counters = next(r["counters"] for r in recs if r.get("kind") == "counters")
for name in ("eta_min_violations", "budget_violations"):
    assert counters.get(name, 0) == 0, f"{name}: {counters[name]}"
steps = [r for r in recs if r.get("kind") == "step"]
assert steps, "no step events"
assert all(r.get("gossip_delay") == 1 for r in steps), \
    [r.get("gossip_delay") for r in steps]
print(f"cli-smoke async: counters OK {counters}, "
      f"{len(steps)} delay-stamped step events")
PY
            then
                echo "cli-smoke $mode: FAIL (async counters)"; rc=1; continue
            fi
        fi
        if [[ "$mode" == lowrank ]]; then
            # kill/resume through the stateful rung: re-invoke with
            # --resume to pick up the step-6 checkpoint and run to 8
            # shellcheck disable=SC2086
            if ! PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
                    python -m repro.launch.train "${COMMON[@]}" \
                    ${FLAGS[$mode]} --steps 8 --resume \
                    --metrics-out "$TMP/lowrank-resume.json" \
                    --obs "$TMP/lowrank-resume.jsonl"; then
                echo "cli-smoke $mode: FAIL (resume exit)"; rc=1; continue
            fi
            if ! python - "$TMP/lowrank.jsonl" "$TMP/lowrank-resume.jsonl" \
                    "$TMP/lowrank-ckpt" <<'PY'
import json, pathlib, sys
for p in sys.argv[1:3]:
    recs = [json.loads(l) for l in open(p)]
    counters = next(r["counters"] for r in recs if r.get("kind") == "counters")
    assert counters.get("eta_min_violations", 0) == 0, (p, counters)
ckpts = sorted(pathlib.Path(sys.argv[3]).glob("step_*"))
assert ckpts, "no checkpoint"
steps = [r["step"] for r in
         (json.loads(l) for l in open(sys.argv[2]))
         if r.get("kind") == "step"]
assert steps and min(steps) > 1, \
    f"resume replayed from scratch: first step event {steps[:1]}"
print(f"cli-smoke lowrank: resume OK ({len(ckpts)} checkpoints, "
      f"resumed steps {min(steps)}..{max(steps)})")
PY
            then
                echo "cli-smoke $mode: FAIL (lowrank resume checks)"; rc=1
                continue
            fi
        fi
        if ! python - "$TMP/$mode.json" "$mode" <<'PY'
import json, sys
rows = json.load(open(sys.argv[1])); mode = sys.argv[2]
assert rows, "no metrics rows"
need = {"loss", "step", "wall_s", "grad_norm"}
if mode != "static":
    need.add("wire")
if mode == "topology":
    need |= {"topology", "eta_min", "eta_min_violations"}
if mode == "async":
    need.add("gossip_delay")
missing = need - set(rows[-1])
assert not missing, f"missing metrics keys: {sorted(missing)}"
if mode == "async":
    assert rows[-1]["gossip_delay"] == 1, rows[-1]["gossip_delay"]
if mode == "topology":
    assert rows[-1]["eta_min_violations"] == 0, \
        f"eta_min violations: {rows[-1]['eta_min_violations']}"
    assert rows[-1]["topology"] == "ring", rows[-1]["topology"]
print(f"cli-smoke {mode}: OK ({len(rows)} rows, "
      f"final loss {rows[-1]['loss']:.3f})")
PY
        then
            echo "cli-smoke $mode: FAIL (metrics check)"; rc=1
        fi
    done
    # serve plane: differential weight sync for 2 decode replicas under a
    # hard per-tick sync budget (sized to the int8 rung on both star
    # links), with checkpointing; the checker gates on zero budget
    # violations, max staleness <= target, and checkpoint presence
    echo "== cli-smoke: serve =="
    if ! PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
            python -m repro.launch.serve_cli --arch xlstm-350m --smoke \
            --replicas 2 --topology star --ticks 6 --gen 2 --batch 2 \
            --prompt-len 4 --wire int8:block=64 --sync-ladder "$LADDER" \
            --sync-budget 3000000 --staleness-target 2 \
            --ckpt-every 3 --ckpt-dir "$TMP/serve-ckpt" \
            --metrics-out "$TMP/serve.json" --obs "$TMP/serve.jsonl"; then
        echo "cli-smoke serve: FAIL (nonzero exit)"; rc=1
    elif ! PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
            python -m repro.launch.obs_cli validate "$TMP/serve.jsonl"; then
        echo "cli-smoke serve: FAIL (obs validate)"; rc=1
    elif ! python - "$TMP/serve.jsonl" "$TMP/serve.json" \
            "$TMP/serve-ckpt" <<'PY'
import json, pathlib, sys
recs = [json.loads(l) for l in open(sys.argv[1])]
counters = next(r["counters"] for r in recs if r.get("kind") == "counters")
assert counters.get("budget_violations", 0) == 0, counters
steps = [r for r in recs if r.get("kind") == "step"]
assert steps, "no step events"
assert all(r.get("staleness") is not None and r["staleness"] <= 2
           for r in steps), [r.get("staleness") for r in steps]
assert all(r.get("sync_bits") is not None and r.get("replica") is not None
           for r in steps), "missing serve sync fields"
rows = json.load(open(sys.argv[2]))
assert rows, "no metrics rows"
need = {"step", "wire", "requests", "sync_bits", "staleness", "tok_s"}
missing = need - set(rows[-1])
assert not missing, f"missing metrics keys: {sorted(missing)}"
assert list(pathlib.Path(sys.argv[3]).glob("step_*")), "no checkpoint"
print(f"cli-smoke serve: OK ({len(steps)} ticks, max staleness "
      f"{max(r['staleness'] for r in steps)}, counters {counters})")
PY
    then
        echo "cli-smoke serve: FAIL (serve checks)"; rc=1
    fi
    exit $rc
fi

# || rc=$? keeps going under set -e so the perf artifact refreshes even
# when tests fail (a nonzero rc from either stage still fails the run)
rc=0
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "${ARGS[@]}" || rc=$?

# refresh the gossip-step perf artifact (artifacts/bench/BENCH_gossip.json)
# on every run: seconds-scale; fails the run on a DETERMINISTIC flat-path
# regression (collective ops / bit-exactness / wire bits)
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --smoke || rc=$?

exit $rc
