#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md), with the dev deps the suite expects.
#
#   scripts/run_tests.sh            # full tier-1 suite
#   scripts/run_tests.sh --fast     # CPU-only split (-m "not multidevice"),
#                                   # stays under ~5 minutes
#   scripts/run_tests.sh <pytest args...>   # passthrough
set -euo pipefail
cd "$(dirname "$0")/.."

# dev deps (hypothesis etc.) — tests degrade to skips without them, so a
# failed install is a warning, not an error (containers may be offline)
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "WARN: pip install -r requirements-dev.txt failed (offline?); " \
            "hypothesis-based property tests will be skipped"

ARGS=("$@")
if [[ "${1:-}" == "--fast" ]]; then
    ARGS=(-m "not multidevice" "${@:2}")
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    exec python -m pytest -x -q "${ARGS[@]}"
