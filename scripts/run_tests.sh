#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md), with the dev deps the suite expects.
#
#   scripts/run_tests.sh            # full tier-1 suite
#   scripts/run_tests.sh --fast     # CPU-only split (-m "not multidevice"),
#                                   # stays under ~5 minutes
#   scripts/run_tests.sh --hypothesis   # property-test split only: seeded
#                                   # (--hypothesis-seed=0) and bounded via
#                                   # the derandomized "repro-ci" profile
#                                   # (tests/conftest.py), so it is
#                                   # deterministic and wall-time-bounded
#   scripts/run_tests.sh <pytest args...>   # passthrough
set -euo pipefail
cd "$(dirname "$0")/.."

# dev deps (hypothesis etc.) — tests degrade to skips without them, so a
# failed install is a warning, not an error (containers may be offline)
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
    || echo "WARN: pip install -r requirements-dev.txt failed (offline?); " \
            "hypothesis-based property tests will be skipped"

ARGS=("$@")
if [[ "${1:-}" == "--fast" ]]; then
    ARGS=(-m "not multidevice" "${@:2}")
elif [[ "${1:-}" == "--hypothesis" ]]; then
    # the property-test files; seeded + derandomized profile => tier-1
    # deterministic.  Without hypothesis installed the files degrade to
    # their seeded fallback tests (and --hypothesis-seed would be an
    # unknown flag), so only pass the seed when the plugin is present.
    ARGS=(tests/test_wire_properties.py tests/test_compressors.py
          tests/test_consensus_greedy.py "${@:2}")
    if python -c "import hypothesis" 2>/dev/null; then
        ARGS+=(--hypothesis-seed=0)
    else
        echo "WARN: hypothesis not installed; running seeded fallbacks only"
    fi
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        exec python -m pytest -x -q "${ARGS[@]}"
fi

# || rc=$? keeps going under set -e so the perf artifact refreshes even
# when tests fail (a nonzero rc from either stage still fails the run)
rc=0
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "${ARGS[@]}" || rc=$?

# refresh the gossip-step perf artifact (artifacts/bench/BENCH_gossip.json)
# on every run: seconds-scale; fails the run on a DETERMINISTIC flat-path
# regression (collective ops / bit-exactness / wire bits)
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --smoke || rc=$?

exit $rc
