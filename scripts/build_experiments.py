"""Assemble EXPERIMENTS.md from artifacts (dryrun/, dryrun_baseline/,
bench/).  Re-runnable: PYTHONPATH=src python scripts/build_experiments.py
"""
import glob
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))
ART = REPO / "artifacts"

PEAK = 197e12
HBM_BW = 819e9
LINK = 50e9


def load(d):
    out = {}
    for f in glob.glob(str(d / "*.json")):
        r = json.loads(Path(f).read_text())
        out[(r["arch"], r["shape"], r["mesh"], r.get("tag", ""))] = r
    return out


def terms(r):
    c = r["hlo_flops_per_device"] / PEAK
    m = r["hlo_hbm_bytes_per_device"] / HBM_BW
    l = r["collectives"]["total"] / LINK
    dom = max((("compute", c), ("memory", m), ("collective", l)),
              key=lambda kv: kv[1])[0]
    frac = c / max(c, m, l) if max(c, m, l) else 0
    return c, m, l, dom, frac


def fmt_cell(r):
    if r["status"] == "skipped":
        return None
    c, m, l, dom, frac = terms(r)
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']:.0f}s | {r['bytes_per_device_gib']:.1f} "
            f"| {c:.3g} | {m:.3g} | {l:.3g} | {dom} | {frac:.2f} |")


def dryrun_section(cur):
    lines = ["## §Dry-run — lower+compile, all 40 cells x {16x16, 2x16x16}",
             "",
             "Every cell `.lower().compile()`s on the production meshes; "
             "`memory_analysis()` (GiB/device, donation-aliased as deployed) "
             "and the trip-count-weighted HLO terms are recorded per cell in "
             "`artifacts/dryrun/*.json`. Skipped cells are the designed "
             "long_500k skips for pure full-attention archs "
             "(DESIGN.md §3).", "",
             "| arch | shape | mesh | compile | GiB/dev | compute s | "
             "memory s | coll s | dominant | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    n_ok = n_skip = 0
    fits = 0
    for key in sorted(cur):
        r = cur[key]
        if key[3]:
            continue
        if r["status"] == "skipped":
            n_skip += 1
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                         f"| — | — | — | SKIP | ({r['reason'][:40]}) |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| ERROR | | | | | | |")
            continue
        n_ok += 1
        fits += r["bytes_per_device_gib"] < 16.0
        lines.append(fmt_cell(r))
    lines.insert(3, f"**{n_ok} cells compile OK, {n_skip} designed skips; "
                 f"{fits}/{n_ok} fit 16 GiB HBM (see §Perf for the fixes "
                 f"that got them there).**")
    return "\n".join(lines)


def roofline_section():
    rows = json.loads((ART / "bench" / "roofline.json").read_text()) \
        if (ART / "bench" / "roofline.json").exists() else []
    md = (ART / "bench" / "roofline.md").read_text() \
        if (ART / "bench" / "roofline.md").exists() else "(run benchmarks)"
    ok = [r for r in rows if r.get("status") == "ok"]
    import numpy as np
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    hdr = [
        "## §Roofline — three terms per (arch x shape x mesh)",
        "",
        "Terms from the compiled dry-run artifacts (per-device, "
        "trip-count-weighted HLO analysis; v5e constants 197 TF bf16, "
        "819 GB/s HBM, 50 GB/s/link ICI).  MODEL_FLOPS = 6·N_active·D "
        "(train) / 2·N_active·D (inference), N_active excludes embeddings "
        "and non-routed experts; `useful ratio` = MODEL_FLOPS/HLO_FLOPs "
        "(captures remat recompute, head padding, causal-tile and capacity "
        "waste).",
        "",
        f"Dominant-term census over {len(ok)} cells: {doms}.",
        f"Median roofline fraction: "
        f"{np.median([r['roofline_fraction'] for r in ok]):.2f}; "
        f"median useful ratio "
        f"{np.median([r['useful_ratio'] for r in ok]):.2f}.",
        "",
    ]
    return "\n".join(hdr) + "\n" + md


def perf_section(cur, base):
    def get(d, a, s, m):
        return d.get((a, s, m, ""))

    def row(r):
        if r is None or r["status"] != "ok":
            return None
        c, mm, l, dom, frac = terms(r)
        return dict(gib=r["bytes_per_device_gib"], c=c, m=mm, l=l, dom=dom,
                    frac=frac, coll=r["collectives"]["total"],
                    hbm=r["hlo_hbm_bytes_per_device"],
                    flops=r["hlo_flops_per_device"],
                    wire=(r.get("wire_stats") or {}))

    out = ["## §Perf — hypothesis -> change -> measure log", ""]
    out.append(
        "Baselines for every cell are frozen in `artifacts/dryrun_baseline/` "
        "(the paper-faithful configuration: DC-DGD with the blocked-ternary "
        "wire, f32 consensus state, bf16 KV).  The three hillclimbed cells "
        "and the global iterations are below; numbers are per-device from "
        "the compiled dry-run.")
    out.append("")

    pairs = [
        ("qwen3-8b", "train_4k", "single",
         "representative of the paper's technique (node=replica DC-DGD)"),
        ("llama4-maverick-400b-a17b", "train_4k", "multi",
         "worst memory / hierarchical pod-consensus + MoE + FSDP"),
        ("qwen1.5-32b", "decode_32k", "single",
         "worst baseline HBM (infeasible at bf16 KV)"),
    ]
    out.append("### Hillclimbed cells (before -> after)\n")
    out.append("| cell | why chosen | GiB/dev | compute s | memory s | "
               "coll s | roofline frac |")
    out.append("|---|---|---|---|---|---|---|")
    for a, s, m, why in pairs:
        b = row(get(base, a, s, m))
        c = row(get(cur, a, s, m))
        if b and c:
            out.append(
                f"| {a} x {s} x {m} | {why} "
                f"| {b['gib']:.1f} → **{c['gib']:.1f}** "
                f"| {b['c']:.3g} → {c['c']:.3g} "
                f"| {b['m']:.3g} → **{c['m']:.3g}** "
                f"| {b['l']:.3g} → **{c['l']:.3g}** "
                f"| {b['frac']:.2f} → **{c['frac']:.2f}** |")
    out.append("")
    return "\n".join(out)


def main():
    cur = load(ART / "dryrun")
    base = load(ART / "dryrun_baseline")
    sections = []
    header = (REPO / "EXPERIMENTS_HEADER.md").read_text() \
        if (REPO / "EXPERIMENTS_HEADER.md").exists() else \
        "# EXPERIMENTS\n"
    sections.append(header)
    sections.append(dryrun_section(cur))
    sections.append("")
    sections.append(roofline_section())
    sections.append("")
    sections.append(perf_section(cur, base))
    perf_log = (REPO / "EXPERIMENTS_PERF_LOG.md")
    if perf_log.exists():
        sections.append(perf_log.read_text())
    (REPO / "EXPERIMENTS.md").write_text("\n".join(sections))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
