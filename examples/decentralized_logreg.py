"""Real-world-style example (paper §V-3): 10-node decentralized logistic
regression with a non-convex regularizer on Spambase-scale data, non-i.i.d.
label-skew split, comparing communication cost across methods.

    PYTHONPATH=src python examples/decentralized_logreg.py
"""
import jax
import numpy as np

from repro.core import baselines, consensus as cons, dcdgd, problems
from repro.core.compressors import HybridChain, Sparsifier, Ternary


def main():
    X, y = problems.spambase_like_data(n=4601, d=57, seed=7)
    prob = problems.logreg_nonconvex(X, y, n_nodes=10, rho=0.1, iid=False)
    W = cons.fig3_topology_b()
    s = cons.spectrum(W)
    eta_min = s.snr_threshold
    print(f"10-node graph: lambda_N={s.lambda_n:.3f} beta={s.beta:.3f} "
          f"SNR threshold {eta_min:.2f}\n")

    alpha, steps = 0.08, 600
    runs = {
        "DGD (uncompressed)": lambda: baselines.run_baseline(
            "dgd", prob, W, alpha, steps, jax.random.PRNGKey(0)),
        "QDGD (int8)": lambda: baselines.run_baseline(
            "qdgd", prob, W, alpha, steps, jax.random.PRNGKey(0)),
        "ADC-DGD (int8, g=1.2)": lambda: baselines.run_baseline(
            "adc-dgd", prob, W, alpha, steps, jax.random.PRNGKey(0)),
        "DC-DGD sparsifier": lambda: dcdgd.run(
            prob, W, Sparsifier(p=min(cons.sparsifier_p_threshold(W) + 0.1,
                                      0.9)),
            alpha, steps, jax.random.PRNGKey(0)),
        "DC-DGD ternary": lambda: dcdgd.run(
            prob, W, Ternary(), alpha, steps, jax.random.PRNGKey(0)),
        "DC-DGD hybrid": lambda: dcdgd.run(
            prob, W, HybridChain(eta=max(1.25 * eta_min, 1.0)), alpha, steps,
            jax.random.PRNGKey(0)),
    }
    print(f"{'method':26s} {'final |grad|^2':>14s} {'Mbits to 3% err':>16s}")
    for name, fn in runs.items():
        r = fn()
        err = np.where(np.isfinite(r["grad_norm_sq"]), r["grad_norm_sq"], 1e12)
        thresh = 0.03 * err[0]
        hit = np.argmax(err < thresh) if (err < thresh).any() else -1
        bits = r["cum_bits"][hit] / 1e6 if hit >= 0 else float("inf")
        print(f"{name:26s} {err[-1]:14.3e} {bits:16.2f}")


if __name__ == "__main__":
    main()
