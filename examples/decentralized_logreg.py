"""Real-world-style example (paper §V-3): 10-node decentralized logistic
regression with a non-convex regularizer on Spambase-scale data, non-i.i.d.
label-skew split, comparing communication cost across methods — driven
through the typed front doors: the graph is a ``repro.topology.TopoSpec``
(``"fig3b"``), and every DC-DGD variant runs as a
``repro.comm.TrainSession`` (``make_dcdgd_session`` + a CommPolicy), the
same driver the launcher and benchmarks use.

    PYTHONPATH=src python examples/decentralized_logreg.py
"""
import jax
import numpy as np

from repro.adapt import make_dcdgd_session
from repro.comm import StaticComm
from repro.core import baselines, consensus as cons, problems
from repro.topology import TopoSpec, topology


def session_run(prob, topo, spec, alpha, steps, key):
    """One DC-DGD variant as a TrainSession over the dcdgd backend: the
    plan key is the compressor spec, the policy is the static baseline."""
    session = make_dcdgd_session(prob, topo, alpha, key, StaticComm(spec))
    res = session.run(steps)
    out = res.metrics_arrays()
    out["cum_bits"] = np.cumsum(out["bits"])
    return out


def main():
    X, y = problems.spambase_like_data(n=4601, d=57, seed=7)
    prob = problems.logreg_nonconvex(X, y, n_nodes=10, rho=0.1, iid=False)
    spec = TopoSpec.parse("fig3b")          # the paper's denser 10-node graph
    W = topology(spec)
    eta_min = W.eta_min
    print(f"10-node graph {spec.canonical()!r}: lambda_N={W.lambda_n:.3f} "
          f"beta={W.beta:.3f} SNR threshold {eta_min:.2f}\n")

    alpha, steps = 0.08, 600
    p_safe = min(cons.sparsifier_p_threshold(W) + 0.1, 0.9)
    key = jax.random.PRNGKey(0)
    runs = {
        "DGD (uncompressed)": lambda: baselines.run_baseline(
            "dgd", prob, W, alpha, steps, key),
        "QDGD (int8)": lambda: baselines.run_baseline(
            "qdgd", prob, W, alpha, steps, key),
        "ADC-DGD (int8, g=1.2)": lambda: baselines.run_baseline(
            "adc-dgd", prob, W, alpha, steps, key),
        "DC-DGD sparsifier": lambda: session_run(
            prob, W, f"sparsifier:p={p_safe}", alpha, steps, key),
        "DC-DGD ternary": lambda: session_run(
            prob, W, "ternary", alpha, steps, key),
        "DC-DGD hybrid": lambda: session_run(
            prob, W, f"hybrid:eta={max(1.25 * eta_min, 1.0)}", alpha,
            steps, key),
    }
    print(f"{'method':26s} {'final |grad|^2':>14s} {'Mbits to 3% err':>16s}")
    for name, fn in runs.items():
        r = fn()
        err = np.where(np.isfinite(r["grad_norm_sq"]), r["grad_norm_sq"], 1e12)
        thresh = 0.03 * err[0]
        hit = np.argmax(err < thresh) if (err < thresh).any() else -1
        bits = r["cum_bits"][hit] / 1e6 if hit >= 0 else float("inf")
        print(f"{name:26s} {err[-1]:14.3e} {bits:16.2f}")


if __name__ == "__main__":
    main()
