"""End-to-end LM training driver: decentralized DC-DGD data-parallel
training of a transformer on the synthetic non-i.i.d. pipeline, with
checkpoint/resume.

    # CPU-sized default (runs in ~2 min):
    PYTHONPATH=src python examples/train_lm.py

    # the ~100M-parameter preset (a few hundred steps; give it a while on CPU
    # or run on real devices):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is a 12L/768d qwen3-family model (~100M params plus
embeddings).  Loss curves land in artifacts/examples/train_lm_<preset>.json.
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax

from repro.compat import set_mesh
from repro.configs import get_smoke
from repro.configs.base import RunConfig, ShapeConfig
from repro.data import SyntheticLMData
from repro.launch.mesh import make_test_mesh
from repro.train import make_trainer

ART = Path(__file__).resolve().parent.parent / "artifacts" / "examples"


def preset(name: str):
    base = get_smoke("qwen3-8b")
    if name == "tiny":
        return dataclasses.replace(base, name="tiny-lm", n_layers=2,
                                   d_model=128, n_heads=4, n_kv_heads=2,
                                   d_ff=512, head_dim=32, vocab_size=2048), 128, 16
    if name == "100m":
        return dataclasses.replace(
            base, name="lm-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_ff=2048, head_dim=64, vocab_size=32768), 512, 16
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--wire", default="hybrid:block=512,top_j=4")
    ap.add_argument("--consensus", default="data")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    arch, seq_len, global_batch = preset(args.preset)
    n_dev = len(jax.devices())
    mesh = make_test_mesh((max(n_dev, 1), 1), ("data", "model"))
    shape = ShapeConfig("ex", seq_len, global_batch, "train")
    run = RunConfig(consensus_axis=args.consensus, wire=args.wire,
                    optimizer="adam", alpha=3e-3, grad_accum=1,
                    topology="ring")
    tr = make_trainer(mesh, arch, run, shape)
    print(f"{arch.name}: nodes={tr.n_nodes} wire={args.wire}")
    if tr.node_mode and tr.n_nodes > 1:
        ws = tr.wire_stats()
        print(f"per-step comm/node: {ws['wire_bits_per_node_step']/8e6:.2f} MB "
              f"({ws['compression_ratio']:.1f}x vs dense)")
    state = tr.init_state(0)
    n_params = sum(int(x.size) for x in jax.tree.leaves(state.x)) // max(tr.n_nodes, 1)
    print(f"params/node: {n_params/1e6:.1f}M")

    step_fn = tr.jit_train_step()
    data = SyntheticLMData(vocab_size=arch.vocab_size, seq_len=seq_len,
                           global_batch=global_batch,
                           n_nodes=max(tr.n_nodes, 1), iid=False, seed=11)
    hist = []
    t0 = time.time()
    mgr = None
    if args.ckpt:
        from repro.ckpt import CheckpointManager
        mgr = CheckpointManager(args.ckpt, every=100)
    with set_mesh(mesh):
        for i in range(args.steps):
            state, m = step_fn(state, data.batch(i))
            if (i + 1) % 10 == 0:
                loss = float(m["loss"])
                nd = float(m.get("noise_power", 0)) / max(
                    float(m.get("diff_power", 1)), 1e-30)
                hist.append({"step": i + 1, "loss": loss, "noise_ratio": nd,
                             "wall_s": round(time.time() - t0, 1)})
                print(f"step {i+1:4d}  loss {loss:.4f}  "
                      f"noise/diff {nd:.3f}  ({hist[-1]['wall_s']}s)")
            if mgr:
                mgr.maybe_save(i + 1, state)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"train_lm_{args.preset}.json").write_text(json.dumps(hist, indent=1))
    assert hist[-1]["loss"] < hist[0]["loss"], "loss did not decrease"
    print(f"done; loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
