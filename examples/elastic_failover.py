"""Fault-tolerance walkthrough: decentralized training survives a node
failure, a node join, simulated link faults, and a checkpoint restart —
the DESIGN.md §6 story, executable on CPU, driven through the typed front
doors: graphs are ``repro.topology`` objects (Membership rebuilds one per
change and re-derives eta_min), every training segment is a
``repro.comm.TrainSession``, and the straggling-link segment composes a
``FaultComm`` over the static policy (drop-and-renormalize per step).

    PYTHONPATH=src python examples/elastic_failover.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.adapt import make_dcdgd_session
from repro.adapt.runner import _metric_step
from repro.ckpt import restore, save
from repro.comm import Compose, FaultComm, StaticComm
from repro.core import dcdgd, problems
from repro.core.compressors import make_compressor
from repro.runtime.elastic import Membership, apply_state_plan, \
    rebuild_consensus
from repro.runtime.fault import StragglerSim, drop_renormalize_dense, \
    peel_plan_key

SPEC = "sparsifier:p=0.8"
ALPHA = 0.08


def warm_state(prob, x0, key):
    """DCDGDState warm-started at x0 with the residual RESET (s = 0, i.e.
    y = x — the apply_state_plan convention after a membership change)."""
    d1 = jax.tree.map(lambda g: -ALPHA * g, prob.grad(x0))
    return dcdgd.DCDGDState(x=x0, y=x0, d=d1, t=jnp.int32(1), key=key)


def run_segment(prob, m, x0, key, steps, policy=None, build_step=None):
    """One training segment on the CURRENT membership graph, through the
    one TrainSession driver.  Returns (x, s) for the next state-carry."""
    session = make_dcdgd_session(prob, m.topo, ALPHA, key,
                                 policy or StaticComm(SPEC),
                                 build_step=build_step)
    key, sub = jax.random.split(key)
    session.state = warm_state(prob, x0, sub)
    res = session.run(steps)
    st = res.state
    return st.x, st.y - st.x, key


def gnorm(prob, x):
    return float(jnp.sum(prob.global_grad(jnp.mean(x, 0)) ** 2))


def main():
    comp_snr = make_compressor(SPEC).snr_lower_bound(8)
    m = Membership(node_ids=[0, 1, 2, 3, 4], topology="ring")
    prob = problems.quadratic(n_nodes=5, dim=8, seed=3)
    info = rebuild_consensus(m, comp_snr)
    print(f"[gate] 5-node {m.topo.canonical()!r}: "
          f"eta_min={info['eta_min']:.3f} ok={info['ok']}")

    x = jnp.zeros((5, 8))
    key = jax.random.PRNGKey(0)
    x, s, key = run_segment(prob, m, x, key, 120)
    print(f"[train] 120 session steps, |grad|^2 = {gnorm(prob, x):.2e}")

    # --- checkpoint, then simulate a crash + restart ---
    with tempfile.TemporaryDirectory() as d:
        save(d, 120, {"x": x, "s": s})
        x2, _ = restore(d, 120, {"x": jax.eval_shape(lambda: x),
                                 "s": jax.eval_shape(lambda: s)})
        print(f"[ckpt] restart drift: "
              f"{float(jnp.abs(x2['x'] - x).max()):.1e} (exact)")

    # --- node 2 dies: Membership rebuilds the Topology, the gate re-runs ---
    plan = m.leave(2)
    x, s = apply_state_plan(x, s, plan)
    prob4 = problems.quadratic(n_nodes=4, dim=8, seed=3)
    info = rebuild_consensus(m, comp_snr)
    print(f"[leave] node 2 gone; {m.topo.canonical()!r} rebuilt "
          f"(eta_min={info['eta_min']:.3f}, doubly stochastic: "
          f"{np.allclose(m.W.sum(0), 1)})")
    x, s, key = run_segment(prob4, m, x, key, 120)
    print(f"[train] post-failure |grad|^2 = {gnorm(prob4, x):.2e}")

    # --- straggling links: FaultComm composes over the static policy ---
    n_edges = int(m.topo.adj.sum()) // 2
    sim = StragglerSim(prob=0.5, seed=7)

    def build_step(key_):
        # plan keys are the spec, ("fault", drops, spec), or "outage"
        # (every edge out that step): lower drops by renormalizing W —
        # the same rule runtime.fault applies to circulant offsets
        from repro.core.compressors import Identity
        from repro.runtime.fault import OUTAGE_SPEC
        if key_ == OUTAGE_SPEC:
            return _metric_step(prob4, lambda t: ALPHA,
                                jnp.eye(m.n, dtype=jnp.float32), Identity())
        _, drops, inner = peel_plan_key(key_)
        W = drop_renormalize_dense(m.W, drops)
        return _metric_step(prob4, lambda t: ALPHA,
                            jnp.asarray(W, jnp.float32),
                            make_compressor(inner))

    faulty = Compose(StaticComm(SPEC),
                     FaultComm(sim=sim, n_classes=n_edges))
    x, s, key = run_segment(prob4, m, x, key, 30, policy=faulty,
                            build_step=build_step)
    print(f"[straggler] 30 steps with 50% per-edge faults "
          f"(FaultComm over {n_edges} edges): "
          f"|grad|^2 = {gnorm(prob4, x):.2e}")

    # --- a new node joins, warm-started from a neighbor ---
    plan = m.join(9)
    x, s = apply_state_plan(x, s, plan)
    prob5 = problems.quadratic(n_nodes=5, dim=8, seed=3)
    info = rebuild_consensus(m, comp_snr)
    print(f"[join] node 9 joined {m.topo.canonical()!r} "
          f"(eta_min={info['eta_min']:.3f}, neighbor-copy init)")
    x, s, key = run_segment(prob5, m, x, key, 150)
    print(f"[train] post-join |grad|^2 = {gnorm(prob5, x):.2e}")
    print("elastic failover cycle complete")


if __name__ == "__main__":
    main()
