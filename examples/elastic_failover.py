"""Fault-tolerance walkthrough: decentralized training survives a node
failure, a node join, simulated link outages, and a checkpoint restart —
the DESIGN.md §6 story, executable on CPU.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import restore, save
from repro.core import consensus as cons, dcdgd, problems
from repro.core.compressors import Sparsifier
from repro.core.gossip import GossipPlan, make_plan  # noqa: F401
from repro.runtime.elastic import Membership, apply_state_plan, \
    rebuild_consensus
from repro.runtime.fault import StragglerSim, drop_renormalize_plan


def grad_step(prob, W, x, s, key, comp, alpha=0.08, drop=None):
    Wj = jnp.asarray(W, jnp.float32)
    if drop:  # drop-and-renormalize: fold dropped edge weight into self
        W = W.copy()
        i, j = drop
        w = W[i, j]
        W[i, j] = W[j, i] = 0.0
        W[i, i] += w
        W[j, j] += w
        Wj = jnp.asarray(W, jnp.float32)
    g = prob.grad(x)
    d = s - alpha * g
    key, sub = jax.random.split(key)
    c = dcdgd._node_compress(comp, sub, d)
    return x + c, s + dcdgd._mix(Wj, c) - c, key


def gnorm(prob, x):
    return float(jnp.sum(prob.global_grad(jnp.mean(x, 0)) ** 2))


def main():
    comp = Sparsifier(p=0.8)
    m = Membership(node_ids=[0, 1, 2, 3, 4], topology="ring")
    prob = problems.quadratic(n_nodes=5, dim=8, seed=3)
    info = rebuild_consensus(m, comp.snr_lower_bound(8))
    print(f"[gate] 5-node ring: eta_min={info['eta_min']:.3f} ok={info['ok']}")

    x = jnp.zeros((5, 8))
    s = jnp.zeros((5, 8))
    key = jax.random.PRNGKey(0)
    for _ in range(120):
        x, s, key = grad_step(prob, m.W, x, s, key, comp)
    print(f"[train] 120 steps, |grad|^2 = {gnorm(prob, x):.2e}")

    # --- checkpoint, then simulate a crash + restart ---
    with tempfile.TemporaryDirectory() as d:
        save(d, 120, {"x": x, "s": s})
        x2, _ = restore(d, 120, {"x": jax.eval_shape(lambda: x),
                                 "s": jax.eval_shape(lambda: s)})
        print(f"[ckpt] restart drift: "
              f"{float(jnp.abs(x2['x'] - x).max()):.1e} (exact)")

    # --- node 2 dies ---
    plan = m.leave(2)
    x, s = apply_state_plan(x, s, plan)
    prob4 = problems.quadratic(n_nodes=4, dim=8, seed=3)
    print(f"[leave] node 2 gone; W rebuilt "
          f"(doubly stochastic: {np.allclose(m.W.sum(0), 1)})")
    for _ in range(120):
        x, s, key = grad_step(prob4, m.W, x, s, key, comp)
    print(f"[train] post-failure |grad|^2 = {gnorm(prob4, x):.2e}")

    # --- straggling link: drop-and-renormalize for 30 steps ---
    sim = StragglerSim(prob=0.5, seed=7)
    for t in range(30):
        drop = (0, 1) if sim.dropped(t, 1) else None
        x, s, key = grad_step(prob4, m.W, x, s, key, comp, drop=drop)
    print(f"[straggler] 30 steps with 50% outage on edge (0,1): "
          f"|grad|^2 = {gnorm(prob4, x):.2e}")

    # --- a new node joins, warm-started from a neighbor ---
    plan = m.join(9)
    x, s = apply_state_plan(x, s, plan)
    prob5 = problems.quadratic(n_nodes=5, dim=8, seed=3)
    for _ in range(150):
        x, s, key = grad_step(prob5, m.W, x, s, key, comp)
    print(f"[join] node 9 joined (neighbor-copy init); "
          f"|grad|^2 = {gnorm(prob5, x):.2e}")
    print("elastic failover cycle complete")


if __name__ == "__main__":
    main()
