"""Elastic-fleet walkthrough: ONE decentralized TrainSession survives a
scripted crash, the crashed node's rejoin, a slow link and a full outage —
then a mid-run kill + crash-consistent resume reproduces it bit-exactly.

This is the DESIGN.md §6 story on the live machinery (it used to be four
separate sessions glued by hand):

  * the scenario is a deterministic ``repro.runtime.chaos.FaultSchedule``
    string — no RNG, no wall clock, reproducible from the script alone;
  * churn is LIVE: ``repro.comm.ElasticComm`` re-keys the stacked (x, s)
    state (``rekey_dcdgd_state``: departures averaged in, the rejoiner
    warm-started from its best-connected neighbor), retargets the
    Theorem-1 floor for the rebuilt graph, and swaps epoch-qualified
    plan-bank entries — the trainer is never rebuilt;
  * the slow link is budget scaling, not a drop: ``ChaosComm`` makes bits
    proportionally more expensive while the span lasts, so the composed
    ``BudgetComm`` buys cheaper rungs;
  * ``SessionCheckpointer`` snapshots the POLICY (ledger, held plans,
    hysteresis) alongside the model state, so a fresh process restored at
    the kill step replays an event-log tail equal to the uninterrupted
    run's (``repro.obs.diff_exact``) with a bit-identical final state.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.adapt import ladder_from_specs
from repro.adapt.budget import BudgetController, BudgetSchedule
from repro.adapt.policies import BudgetPolicy
from repro.adapt.runner import _metric_step, make_dcdgd_session
from repro.comm import (BudgetComm, Compose, ElasticComm, OutageComm,
                        SessionCheckpointer, StaticComm, restore_policy)
from repro.core import problems
from repro.core.compressors import Identity, WireCompressor
from repro.core.wire import make_wire
from repro.obs import JsonlSink, Recorder, diff_exact
from repro.runtime.chaos import ChaosComm, FaultSchedule
from repro.runtime.elastic import (Membership, rekey_dcdgd_state,
                                   restrict_problem)
from repro.runtime.fault import OUTAGE_SPEC, peel_plan_key
from repro.topology import TopoSchedule, TopologyComm

N, DIM, STEPS = 5, 8, 120
ALPHA = 0.08
LADDER = ("dense", "int8:block=8", "ternary:block=8")
BUDGET = 600.0                     # affords int8 on (5, 8), never dense
SCHEDULE = ("crash:node=2,at=30 | rejoin:node=2,at=60 | "
            "slow:edge=0-1,span=70:90,factor=0.5 | outage:span=95:100")
CKPT_EVERY = 20
KILL_AT = 40                       # inside the 4-node epoch (30 <= k < 60)


def build_run(obs_path, ckpt_dir=None):
    """A complete fresh harness (membership, registries, composed policy,
    session) — the resume path calls this again to prove a new process
    reconstructs everything from config + checkpoint alone."""
    prob = problems.quadratic(n_nodes=N, dim=DIM, seed=3)
    sched = FaultSchedule.parse(SCHEDULE)
    mem = Membership(list(range(N)), topology="ring")
    opening = mem.topo
    alpha_fn = lambda t: ALPHA                               # noqa: E731

    topo_sched = TopoSchedule(entries=((0, "ring"),))
    topo_comm = TopologyComm(
        schedule=topo_sched,
        topologies={topo_sched.entries[0][1].canonical(): opening},
        dims=None,
        guaranteed_snr=lambda s: make_wire(s).snr_lower_bound(1))
    opening_c = topo_comm._active

    # registries the bank builder and churn hooks share: epoch key -> W /
    # restricted problem; "current" tracks the live epoch for OUTAGE
    Ws = {opening_c: np.asarray(opening.W)}
    probs = {opening_c: prob}
    current = {"key": opening_c}

    def register_hook(key_, topo, node_ids):
        Ws[key_] = np.asarray(topo.W)
        probs[key_] = restrict_problem(prob, node_ids)
        current["key"] = key_

    def build_step(key_):
        if key_ == OUTAGE_SPEC:
            p = probs[current["key"]]
            return _metric_step(p, alpha_fn,
                                jnp.eye(p.n_nodes, dtype=jnp.float32),
                                Identity())
        topo_c, drops, inner = peel_plan_key(key_)
        assert not drops, key_
        W = jnp.asarray(Ws[topo_c or opening_c], jnp.float32)
        comp = WireCompressor(fmt=make_wire(inner))
        return _metric_step(probs[topo_c or opening_c], alpha_fn, W, comp)

    recorder = Recorder(JsonlSink(obs_path))
    recorder.emit_manifest(config={"chaos": sched.canonical(),
                                   "budget": BUDGET},
                           topology=opening_c, seed=0)
    session = make_dcdgd_session(prob, opening.W, alpha_fn,
                                 jax.random.PRNGKey(0), None,
                                 bank_size=16, build_step=build_step,
                                 obs=recorder)

    def state_hook(plan, topo, node_ids, key_):
        session.state = rekey_dcdgd_state(session.state, plan,
                                          probs[key_].grad, ALPHA)

    elastic = ElasticComm(
        membership=mem, topo_comm=topo_comm, events=sched.churn_events(),
        state_hook=state_hook, register_hook=register_hook,
        shapes_fn=lambda n: ((n, DIM),))
    budget = BudgetComm(policy=BudgetPolicy(
        controller=BudgetController(
            ladder=ladder_from_specs(LADDER, level="wire"),
            shapes=((N, DIM),), neighbors=1, eta_min=opening.eta_min),
        schedule=BudgetSchedule(bits=BUDGET), cadence=1))
    chaos = ChaosComm(schedule=sched,
                      n_edges=int(np.asarray(opening.adj).sum()) // 2)
    policy = Compose(StaticComm(LADDER[1]), budget, elastic, chaos,
                     OutageComm(windows=sched.outage_windows()))
    session.policy = policy

    ckptr = None
    if ckpt_dir is not None:
        ckptr = SessionCheckpointer(directory=str(ckpt_dir), policy=policy,
                                    every=CKPT_EVERY, retain=0)
        session.checkpoint = ckptr
    return session, policy, elastic, recorder, prob


def main():
    with tempfile.TemporaryDirectory() as tmp:
        tmp = Path(tmp)
        ckpt_dir, base_log, resume_log = \
            tmp / "ckpt", tmp / "run.jsonl", tmp / "resume.jsonl"

        # --- the uninterrupted chaos run (checkpointing as it goes) ------
        session, policy, elastic, recorder, prob = \
            build_run(base_log, ckpt_dir=ckpt_dir)
        print(f"[gate] {N}-node ring: eta_min="
              f"{elastic.membership.topo.eta_min:.3f}; chaos script: "
              f"{FaultSchedule.parse(SCHEDULE).canonical()!r}")
        res = session.run(STEPS)
        recorder.close()
        for at, kind, node, key_ in elastic.churn_log:
            print(f"[churn] step {at}: {kind} node {node} -> {key_}")
        x = np.asarray(res.state.x)
        gap = float(res.metrics_arrays()["f_bar"][-1] - prob.f_star)
        print(f"[train] {STEPS} steps on ONE session through crash/rejoin/"
              f"slow/outage: state {x.shape}, final gap {gap:.2e}, "
              f"bank {res.bank_stats}")
        assert x.shape == (N, DIM) and len(elastic.churn_log) == 2

        # --- kill at step KILL_AT + crash-consistent resume --------------
        from repro.ckpt import checkpoint as ck
        session2, policy2, _, recorder2, _ = build_run(resume_log)
        state2, manifest = ck.restore(ckpt_dir, KILL_AT, session2.state,
                                      strict_shapes=False)
        restore_policy(policy2, manifest["extra"]["policy"])
        session2.state = state2
        res2 = session2.run(STEPS, start_step=KILL_AT)
        recorder2.close()

        exact = diff_exact(str(base_log), str(resume_log),
                           from_step=KILL_AT)
        bit_equal = all(np.array_equal(np.asarray(a), np.asarray(b))
                        for a, b in zip(jax.tree.leaves(res.state),
                                        jax.tree.leaves(res2.state)))
        print(f"[ckpt] killed at {KILL_AT} (4-node epoch), resumed: "
              f"{exact['n_steps']}-step event tail exact={exact['ok']}, "
              f"final state bit-equal={bit_equal}")
        assert exact["ok"] and bit_equal, exact["mismatches"]
    print("elastic failover cycle complete")


if __name__ == "__main__":
    main()
