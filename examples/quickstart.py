"""Quickstart: the paper's 5-node circle network (objective (14)) solved
with DC-DGD under three compressors, vs the uncompressed DGD baseline.

    PYTHONPATH=src python examples/quickstart.py

Shows: the Theorem-1 SNR gate, convergence parity with DGD, the
self-noise-reduction effect, and per-step communication cost.
"""
import jax
import numpy as np

from repro.core import baselines, consensus as cons, dcdgd, problems
from repro.core.compressors import HybridChain, Sparsifier, Ternary
from repro.topology import topology


def main():
    prob = problems.paper_objective_5node(dim=5, seed=0)
    W = topology("w1")            # the paper's 5-node circle matrix
    s = W.spectrum
    print(f"consensus: 5-node circle, lambda_N={s.lambda_n:.3f}, "
          f"beta={s.beta:.3f}")
    print(f"Theorem-1 SNR threshold: {s.snr_threshold:.3f} "
          f"(sparsifier needs p > {cons.sparsifier_p_threshold(W):.3f})\n")

    steps, alpha = 400, 0.1
    dgd = baselines.run_baseline("dgd", prob, W, alpha, steps,
                                 jax.random.PRNGKey(0))
    print(f"{'method':34s} {'final |grad|^2':>14s} {'Mbits sent':>12s}")
    print(f"{'DGD (uncompressed)':34s} {dgd['grad_norm_sq'][-1]:14.3e} "
          f"{dgd['cum_bits'][-1]/1e6:12.2f}")

    for comp in (Sparsifier(p=0.8), Sparsifier(p=0.5), Ternary(),
                 HybridChain(eta=1.2 * s.snr_threshold)):
        ok, msg = cons.validate_compressor_for_topology(
            W, comp.snr_lower_bound(prob.dim), strict=False)
        r = dcdgd.run(prob, W, comp, alpha, steps, jax.random.PRNGKey(0))
        g = r["grad_norm_sq"][-1]
        tag = "gate: OK " if ok else "gate: WARN"
        print(f"DC-DGD/{comp.name:27s} {g:14.3e} {r['cum_bits'][-1]/1e6:12.2f}"
              f"   [{tag}]")

    # self-noise-reduction: compression noise power over time
    r = dcdgd.run(prob, W, Sparsifier(p=0.8), alpha, steps,
                  jax.random.PRNGKey(0))
    n = r["noise_power"]
    print(f"\nself-noise-reduction (Sparsifier p=0.8): "
          f"E||eps||^2 step 10: {n[10]:.2e} -> step {steps}: {n[-1]:.2e} "
          f"(x{n[10]/max(n[-1],1e-30):.0f} smaller, no damping parameter)")


if __name__ == "__main__":
    main()
