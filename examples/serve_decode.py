"""Serving example: prefill a batch of prompts, then batched greedy decode
against the sharded KV/SSM cache — runs every assigned architecture's
reduced config on CPU.

    PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-8b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_smoke
from repro.models import alloc_cache, decode_step, init_model, prefill


def serve(name: str, batch=2, prompt_len=16, gen=24):
    cfg = get_smoke(name)
    params = init_model(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    batch_in = {"tokens": toks}
    if cfg.encdec:
        batch_in["enc_embeds"] = jax.random.normal(
            key, (batch, min(cfg.frontend_len, prompt_len), cfg.d_model),
            jnp.bfloat16)
    cache = alloc_cache(cfg, batch, prompt_len + gen)
    t0 = time.time()
    logits, cache = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))(
        params, batch_in, cache)
    dstep = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
    out = []
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    for i in range(gen):
        out.append(tok)
        logits, cache = dstep(params, tok, cache, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    dt = time.time() - t0
    seqs = jnp.stack(out, 1)
    print(f"{name:28s} generated {seqs.shape} in {dt:5.1f}s "
          f"({batch * gen / dt:6.1f} tok/s) sample: {seqs[0, :8].tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    args = ap.parse_args()
    names = [args.arch] if args.arch else list(ARCH_NAMES)
    for name in names:
        serve(name)


if __name__ == "__main__":
    main()
