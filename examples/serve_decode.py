"""Serving example: decode replicas live-tracking a moving training fleet.

Migrated onto :class:`repro.serve.ServeSession` — a ScriptedFleet drifts
the weights every tick while the session interleaves batched greedy
decode with differential-coded weight sync (DC-DGD applied to the serve
plane: only d_t = x_t - x_hat_{t-1} crosses the wire).  The printed
tracking error ||x_hat - x|| / ||x|| shows the replicas staying glued to
the fleet at a fraction of full-broadcast bits; the decoded tokens come
from the live, continuously-updated params.

    PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-8b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_smoke
from repro.models import alloc_cache, decode_step, init_model, prefill
from repro.serve import (FreshnessController, ScriptedFleet, ServeSession,
                         WeightDeltaWire)


def serve(name: str, batch=2, prompt_len=16, ticks=6, gen=4):
    cfg = get_smoke(name)
    params = init_model(jax.random.PRNGKey(0), cfg)
    leaves, treedef = jax.tree.flatten(params)
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    batch_in = {"tokens": toks}
    if cfg.encdec:
        batch_in["enc_embeds"] = jax.random.normal(
            key, (batch, min(cfg.frontend_len, prompt_len), cfg.d_model),
            jnp.bfloat16)
    cache = alloc_cache(cfg, batch, prompt_len + ticks * gen)
    logits, cache = jax.jit(lambda p, b, c: prefill(p, cfg, b, c))(
        params, batch_in, cache)
    dstep = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
    box = {"params": params, "cache": cache,
           "tok": jnp.argmax(logits[:, :cfg.vocab_size], -1)
           .astype(jnp.int32), "pos": prompt_len, "out": []}

    def decode_fn(tick):
        ts = time.perf_counter()
        for _ in range(gen):
            box["out"].append(box["tok"])
            lg, box["cache"] = dstep(box["params"], box["tok"],
                                     box["cache"], jnp.int32(box["pos"]))
            box["tok"] = jnp.argmax(lg[:, :cfg.vocab_size], -1) \
                .astype(jnp.int32)
            box["pos"] += 1
        box["tok"].block_until_ready()
        return (batch * gen, time.perf_counter() - ts)

    def on_sync(tick, applied_leaves):
        # fold the decoded differential into the live decode params
        delta = jax.tree.unflatten(treedef, list(applied_leaves))
        box["params"] = jax.tree.map(
            lambda a, d: a + d.astype(a.dtype), box["params"], delta)

    wire = WeightDeltaWire([l.shape for l in leaves])

    def on_log(i, m, ran):
        x = session.state["fleet"]
        xh = session.state["xhat"]
        num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(xh, x))
        den = sum(float(jnp.sum(a ** 2)) for a in x)
        err = (num / max(den, 1e-30)) ** 0.5
        print(f"  tick {i}: wire {str(ran):24s} "
              f"sync {m['sync_bits']:.3g} bits  tracking err {err:.2e}  "
              f"{m['requests'] / max(m['decode_wall_s'], 1e-9):6.1f} tok/s")

    session = ServeSession(
        wire=wire,
        policy=FreshnessController(
            ladder=("dense", "int8:block=64", "ternary:block=64"),
            staleness_target=2.0, start_index=1, upgrade=0.0),
        fleet=ScriptedFleet(seed=7, eta=0.01),
        state=ServeSession.init_state(leaves, n_replicas=2),
        n_replicas=2, decode_fn=decode_fn, on_sync=on_sync,
        log_every=1, on_log=on_log)
    print(f"{name}:")
    res = session.run(ticks)
    seqs = jnp.stack(box["out"], 1)
    print(f"{name:28s} generated {seqs.shape} over {res.n_ticks} ticks "
          f"({res.sync_bits:.3g} sync bits, max staleness "
          f"{res.max_staleness}) sample: {seqs[0, :8].tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    args = ap.parse_args()
    names = [args.arch] if args.arch else list(ARCH_NAMES)
    for name in names:
        serve(name)


if __name__ == "__main__":
    main()
